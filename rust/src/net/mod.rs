//! Streaming HTTP/1.1 front-end over the continuous-batching decode
//! scheduler — the wire protocol of the multi-adapter serving stack.
//!
//! Dependency-free by construction (std `TcpListener` + the crate's own
//! `util/json.rs`), threaded by design:
//!
//! * ONE engine thread owns the `ModelServer` + `KvCache` +
//!   [`crate::serve::DecodeScheduler`] and runs the continuous-batching
//!   loop ([`engine::run_engine`]), streaming every sampled token back
//!   to its connection over a per-request channel,
//! * ONE listener thread accepts connections onto a BOUNDED queue
//!   (overflow answers an immediate 503 — backpressure, not OOM),
//! * N connection workers pull from the queue, parse one request each
//!   ([`http`]), validate it ([`api`]), pass admission control
//!   ([`tenant`]), and forward to the engine,
//! * a [`drain::DrainState`] coordinates graceful shutdown: stop
//!   admitting, finish every running sequence, flush every stream, exit
//!   (SIGTERM/SIGINT optional via [`drain::install_signal_handlers`]).
//!
//! Endpoints: `POST /v1/generate` (NDJSON token streaming over chunked
//! transfer-encoding, or one-shot JSON with `"stream": false`),
//! `GET /healthz`, `GET /metrics`, `POST /admin/drain`. Status codes
//! mirror [`crate::serve::ServeError::http_status`]; 429s carry
//! `Retry-After` + `X-RateLimit-Remaining`.

pub mod api;
pub mod drain;
pub mod engine;
pub mod http;
pub mod tenant;

pub use api::{ApiContext, ApiError, GenerateRequest};
pub use drain::{DrainState, Phase};
pub use engine::{EngineMsg, StreamEvent, TierRuntime};
pub use http::{HttpRequest, HttpResponse, StreamingClient};
pub use tenant::{Admission, AdmissionControl, TenantPolicy};

use crate::adapter::{AdapterEngine, TierManager};
use crate::serve::{ModelServer, SeqRequest, ServeConfig};
use crate::util::json::{jnum, jstr, Json};
use crate::util::timer::Timer;
use anyhow::Result;
use std::collections::BTreeSet;
use std::io::{BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, Sender, SyncSender, TrySendError};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// How long a worker waits for an engine reply (health/metrics) before
/// reporting the engine unresponsive.
const REPLY_TIMEOUT: Duration = Duration::from_secs(5);

/// Front-end configuration.
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Bind address; port 0 picks a free port (see [`NetServer::addr`]).
    pub addr: String,
    /// Connection worker threads (concurrent in-flight HTTP requests).
    pub workers: usize,
    /// Bounded accept queue depth; overflow is an immediate 503.
    pub accept_backlog: usize,
    /// Largest accepted request body.
    pub max_body_bytes: usize,
    /// Admission policy for tenants without an explicit override.
    pub default_policy: TenantPolicy,
    /// Per-tenant (adapter-name) policy overrides.
    pub tenant_policies: Vec<(String, TenantPolicy)>,
    /// Install SIGTERM/SIGINT handlers that begin a graceful drain.
    pub handle_signals: bool,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            addr: "127.0.0.1:0".to_string(),
            workers: 16,
            accept_backlog: 64,
            max_body_bytes: 1 << 20,
            default_policy: TenantPolicy::default(),
            tenant_policies: Vec::new(),
            handle_signals: false,
        }
    }
}

/// Immutable state shared by every connection worker.
struct Shared {
    ctx: ApiContext,
    drain: Arc<DrainState>,
    admission: Mutex<AdmissionControl>,
    /// Server boot clock — the token buckets' time source.
    clock: Timer,
    max_body: usize,
}

/// RAII in-flight permit: releases the tenant's admission slot when the
/// request finishes, on every exit path.
struct Permit<'a> {
    shared: &'a Shared,
    adapter: Option<String>,
}

impl Drop for Permit<'_> {
    fn drop(&mut self) {
        if let Ok(mut ac) = self.shared.admission.lock() {
            ac.release(self.adapter.as_deref());
        }
    }
}

/// A running HTTP front-end. Dropping it WITHOUT calling
/// [`NetServer::shutdown`] leaves the threads running detached; the
/// clean exit is `begin_drain` (or SIGTERM) followed by `shutdown`.
pub struct NetServer {
    addr: SocketAddr,
    engine_tx: Sender<EngineMsg>,
    drain: Arc<DrainState>,
    stop_listener: Arc<AtomicBool>,
    engine_handle: JoinHandle<()>,
    listener_handle: JoinHandle<()>,
    worker_handles: Vec<JoinHandle<()>>,
}

impl NetServer {
    /// Build the model server synchronously (config errors surface here,
    /// not on a thread), bind, and start the thread ensemble. Every
    /// adapter attached in `engine` is served hot forever; for a
    /// budgeted multi-tenant front-end see [`NetServer::start_tiered`].
    pub fn start(
        engine: &AdapterEngine,
        serve_cfg: ServeConfig,
        net_cfg: NetConfig,
    ) -> Result<NetServer> {
        let server = ModelServer::new(engine, serve_cfg)?;
        Self::start_inner(server, None, net_cfg)
    }

    /// Start with adapter residency tiering: the engine thread takes
    /// ownership of `engine` (promotion needs its factors; demotion
    /// spills them) plus a [`TierManager`], registers every attached
    /// adapter hot, and runs the attach-on-miss / LRU-evict hook at
    /// each step boundary. Names registered cold on `tiers` are
    /// routable immediately — their first request pays the attach.
    pub fn start_tiered(
        engine: AdapterEngine,
        mut tiers: TierManager,
        serve_cfg: ServeConfig,
        net_cfg: NetConfig,
    ) -> Result<NetServer> {
        let server = ModelServer::new(&engine, serve_cfg)?;
        let attached: Vec<String> = engine.names().iter().map(|s| s.to_string()).collect();
        for name in &attached {
            if tiers.tier(name).is_none() {
                tiers.register_hot(name, &engine, &server)?;
            }
        }
        Self::start_inner(server, Some(TierRuntime { engine, tiers }), net_cfg)
    }

    fn start_inner(
        server: ModelServer,
        tiers: Option<TierRuntime>,
        net_cfg: NetConfig,
    ) -> Result<NetServer> {
        let cache = server.new_cache()?;
        // The routable tenant set: everything the server snapshot serves
        // plus (under tiering) every warm/cold registered name — those
        // are attached on miss, not 404'd.
        let mut adapters: BTreeSet<String> =
            server.adapter_names().iter().map(|s| s.to_string()).collect();
        if let Some(tr) = &tiers {
            adapters.extend(tr.tiers.names().iter().map(|s| s.to_string()));
        }
        let ctx = ApiContext { vocab: server.vocab(), max_seq: server.cfg().max_seq, adapters };
        let listener = TcpListener::bind(&net_cfg.addr)?;
        let addr = listener.local_addr()?;

        let drain = Arc::new(DrainState::new());
        if net_cfg.handle_signals {
            drain::install_signal_handlers();
            drain::spawn_signal_watcher(Arc::clone(&drain));
        }

        let (engine_tx, engine_rx) = mpsc::channel::<EngineMsg>();
        let engine_drain = Arc::clone(&drain);
        let engine_handle = std::thread::Builder::new()
            .name("pissa-engine".into())
            .spawn(move || engine::run_engine(server, cache, engine_rx, engine_drain, tiers))?;

        let mut admission = AdmissionControl::new(net_cfg.default_policy);
        for (tenant, policy) in &net_cfg.tenant_policies {
            admission.set_policy(tenant, *policy);
        }
        let shared = Arc::new(Shared {
            ctx,
            drain: Arc::clone(&drain),
            admission: Mutex::new(admission),
            clock: Timer::start(),
            max_body: net_cfg.max_body_bytes,
        });

        // Bounded accept queue: listener pushes, workers pull.
        let (conn_tx, conn_rx) = mpsc::sync_channel::<TcpStream>(net_cfg.accept_backlog.max(1));
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        let mut worker_handles = Vec::with_capacity(net_cfg.workers.max(1));
        for i in 0..net_cfg.workers.max(1) {
            let rx = Arc::clone(&conn_rx);
            let shared = Arc::clone(&shared);
            let tx = engine_tx.clone();
            worker_handles.push(
                std::thread::Builder::new()
                    .name(format!("pissa-http-{i}"))
                    .spawn(move || worker_loop(&rx, &shared, &tx))?,
            );
        }

        let stop_listener = Arc::new(AtomicBool::new(false));
        let listener_stop = Arc::clone(&stop_listener);
        let listener_handle = std::thread::Builder::new()
            .name("pissa-listen".into())
            .spawn(move || listener_loop(listener, conn_tx, &listener_stop))?;

        Ok(NetServer {
            addr,
            engine_tx,
            drain,
            stop_listener,
            engine_handle,
            listener_handle,
            worker_handles,
        })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn phase(&self) -> Phase {
        self.drain.phase()
    }

    /// Stop admitting; in-flight sequences keep running.
    pub fn begin_drain(&self) {
        self.drain.begin_drain();
    }

    /// Block until every admitted sequence has finished and the engine
    /// thread has exited (only terminates after a drain has begun).
    pub fn wait_engine_stopped(&self) {
        self.drain.wait_engine_stopped();
    }

    /// Fetch a `/metrics`-equivalent snapshot in-process.
    pub fn metrics(&self) -> Result<Json> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.engine_tx
            .send(EngineMsg::Metrics { reply: reply_tx })
            .map_err(|_| anyhow::anyhow!("engine stopped"))?;
        Ok(reply_rx.recv_timeout(REPLY_TIMEOUT)?)
    }

    /// Graceful shutdown: drain, finish every running sequence, flush
    /// every stream, stop the listener, join every thread.
    pub fn shutdown(self) -> Result<()> {
        self.drain.begin_drain();
        self.drain.wait_engine_stopped();
        self.engine_handle.join().map_err(|_| anyhow::anyhow!("engine thread panicked"))?;
        // Unblock the (blocking) accept so the listener sees the flag.
        self.stop_listener.store(true, Ordering::Release);
        let _ = TcpStream::connect(self.addr);
        self.listener_handle.join().map_err(|_| anyhow::anyhow!("listener thread panicked"))?;
        // The listener dropped conn_tx; workers drain the queue and exit.
        for h in self.worker_handles {
            h.join().map_err(|_| anyhow::anyhow!("worker thread panicked"))?;
        }
        Ok(())
    }
}

fn listener_loop(listener: TcpListener, conn_tx: SyncSender<TcpStream>, stop: &AtomicBool) {
    for stream in listener.incoming() {
        if stop.load(Ordering::Acquire) {
            return;
        }
        let Ok(stream) = stream else { continue };
        match conn_tx.try_send(stream) {
            Ok(()) => {}
            Err(TrySendError::Full(mut stream)) => {
                // Accept queue full: shed load with an immediate 503
                // instead of queueing unboundedly.
                let api = ApiError::new(503, "overloaded", "accept queue full").retry_after(0.5);
                let hdr = api.retry_after_header();
                let _ = http::write_json_response(&mut stream, 503, &hdr, &api.to_json());
            }
            Err(TrySendError::Disconnected(_)) => return,
        }
    }
}

fn worker_loop(
    conn_rx: &Arc<Mutex<Receiver<TcpStream>>>,
    shared: &Arc<Shared>,
    engine_tx: &Sender<EngineMsg>,
) {
    loop {
        // Hold the lock only for the recv handoff, not the request.
        let stream = {
            let guard = match conn_rx.lock() {
                Ok(g) => g,
                Err(_) => return,
            };
            guard.recv()
        };
        match stream {
            Ok(s) => handle_connection(s, shared, engine_tx),
            Err(_) => return, // listener gone and queue drained
        }
    }
}

/// Serve one connection: exactly one request, `Connection: close`.
fn handle_connection(stream: TcpStream, shared: &Shared, engine_tx: &Sender<EngineMsg>) {
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut stream = stream;
    let req = match http::read_request(&mut reader, shared.max_body) {
        Ok(r) => r,
        Err(e) => {
            let api = ApiError::new(e.status, "bad_request", e.message);
            let _ = http::write_json_response(&mut stream, api.status, &[], &api.to_json());
            return;
        }
    };
    match (req.method.as_str(), req.target.as_str()) {
        ("POST", "/v1/generate") => handle_generate(stream, &req, shared, engine_tx),
        ("GET", "/healthz") => handle_health(stream, shared, engine_tx),
        ("GET", "/metrics") => handle_metrics(stream, shared, engine_tx),
        ("POST", "/admin/drain") => {
            shared.drain.begin_drain();
            let mut o = Json::obj();
            o.set("draining", Json::Bool(true));
            let _ = http::write_json_response(&mut stream, 200, &[], &o);
        }
        (_, "/v1/generate") | (_, "/healthz") | (_, "/metrics") | (_, "/admin/drain") => {
            let api = ApiError::new(405, "method_not_allowed", "wrong method for this endpoint");
            let _ = http::write_json_response(&mut stream, 405, &[], &api.to_json());
        }
        (_, target) => {
            let api = ApiError::new(404, "not_found", format!("no route for '{target}'"));
            let _ = http::write_json_response(&mut stream, 404, &[], &api.to_json());
        }
    }
}

fn handle_health(mut stream: TcpStream, shared: &Shared, engine_tx: &Sender<EngineMsg>) {
    let (reply_tx, reply_rx) = mpsc::channel();
    let alive = engine_tx.send(EngineMsg::Health { reply: reply_tx }).is_ok();
    let body = if alive { reply_rx.recv_timeout(REPLY_TIMEOUT).ok() } else { None };
    match body {
        Some(j) => {
            let ready = j.get("ready").and_then(|v| v.as_bool()).unwrap_or(false);
            let status = if ready { 200 } else { 503 };
            let _ = http::write_json_response(&mut stream, status, &[], &j);
        }
        None => {
            let mut o = Json::obj();
            o.set("ready", Json::Bool(false));
            o.set("phase", jstr(shared.drain.phase().name()));
            let _ = http::write_json_response(&mut stream, 503, &[], &o);
        }
    }
}

fn handle_metrics(mut stream: TcpStream, shared: &Shared, engine_tx: &Sender<EngineMsg>) {
    let (reply_tx, reply_rx) = mpsc::channel();
    let alive = engine_tx.send(EngineMsg::Metrics { reply: reply_tx }).is_ok();
    let body = if alive { reply_rx.recv_timeout(REPLY_TIMEOUT).ok() } else { None };
    match body {
        Some(mut j) => {
            j.set("phase", jstr(shared.drain.phase().name()));
            if let Ok(ac) = shared.admission.lock() {
                j.set("tenants", ac.to_json());
            }
            let _ = http::write_json_response(&mut stream, 200, &[], &j);
        }
        None => {
            let api = ApiError::new(503, "stopped", "engine is not running");
            let _ = http::write_json_response(&mut stream, 503, &[], &api.to_json());
        }
    }
}

fn handle_generate(
    mut stream: TcpStream,
    req: &HttpRequest,
    shared: &Shared,
    engine_tx: &Sender<EngineMsg>,
) {
    if !shared.drain.accepting() {
        let api = ApiError::new(503, "draining", "server is draining").retry_after(1.0);
        let hdr = api.retry_after_header();
        let _ = http::write_json_response(&mut stream, 503, &hdr, &api.to_json());
        return;
    }
    let gen = match api::parse_generate(&req.body, &shared.ctx) {
        Ok(g) => g,
        Err(api) => {
            let _ = http::write_json_response(&mut stream, api.status, &[], &api.to_json());
            return;
        }
    };
    // Admission control BEFORE the engine sees anything.
    let now = shared.clock.secs();
    let verdict = match shared.admission.lock() {
        Ok(mut ac) => ac.admit(gen.adapter.as_deref(), now),
        Err(_) => return,
    };
    match verdict {
        Admission::Granted => {}
        Admission::RateLimited { retry_after_s } => {
            let api = ApiError::new(429, "rate_limited", "tenant token bucket is empty")
                .retry_after(retry_after_s);
            let remaining = match shared.admission.lock() {
                Ok(ac) => ac.remaining(gen.adapter.as_deref(), now),
                Err(_) => 0.0,
            };
            // Header and body share the clamped value from the setter —
            // an unlimited-ETA tenant (rate 0) caps at MAX_RETRY_AFTER_S
            // instead of saturating `u64`.
            let mut hdr = api.retry_after_header();
            let remaining = format!("{}", remaining.floor() as u64);
            hdr.push(("x-ratelimit-remaining".to_string(), remaining));
            let _ = http::write_json_response(&mut stream, 429, &hdr, &api.to_json());
            return;
        }
        Admission::Saturated { inflight, max_inflight } => {
            let api = ApiError::new(
                503,
                "saturated",
                format!("tenant has {inflight}/{max_inflight} requests in flight"),
            )
            .retry_after(1.0);
            let hdr = api.retry_after_header();
            let _ = http::write_json_response(&mut stream, 503, &hdr, &api.to_json());
            return;
        }
    }
    let _permit = Permit { shared, adapter: gen.adapter.clone() };

    let seq_req = SeqRequest {
        adapter: gen.adapter.clone(),
        prompt: gen.prompt.clone(),
        max_new: gen.max_new,
        stop_token: gen.stop_token,
    };
    let (events_tx, events_rx) = mpsc::channel::<StreamEvent>();
    if engine_tx.send(EngineMsg::Submit { req: seq_req, events: events_tx }).is_err() {
        let api = ApiError::new(503, "stopped", "engine is not running");
        let _ = http::write_json_response(&mut stream, 503, &[], &api.to_json());
        return;
    }

    // The first event decides the status line (deferred status): a
    // rejected sequence answers its typed error; a token or an
    // immediate Done answers 200.
    let first = match events_rx.recv() {
        Ok(ev) => ev,
        Err(_) => {
            let api = ApiError::new(500, "engine_failure", "engine hung up without an event");
            let _ = http::write_json_response(&mut stream, 500, &[], &api.to_json());
            return;
        }
    };
    if let StreamEvent::Error(api) = first {
        let hdr = api.retry_after_header();
        let _ = http::write_json_response(&mut stream, api.status, &hdr, &api.to_json());
        return;
    }
    if gen.stream {
        stream_response(stream, &gen, first, &events_rx);
    } else {
        collect_response(stream, first, &events_rx);
    }
}

/// Streaming mode: NDJSON lines over chunked transfer-encoding.
fn stream_response(
    stream: TcpStream,
    gen: &GenerateRequest,
    first: StreamEvent,
    events: &Receiver<StreamEvent>,
) {
    let Ok(mut w) = http::ChunkedWriter::start(stream, 200, &[]) else { return };
    let meta = api::meta_line(0, gen.adapter.as_deref());
    if w.chunk(format!("{meta}\n").as_bytes()).is_err() {
        return;
    }
    let mut ev = first;
    loop {
        let line = match &ev {
            StreamEvent::Token { token, first } => api::token_line(*token, *first),
            StreamEvent::Done { finished } => {
                let _ = w.chunk(format!("{}\n", api::done_line(finished)).as_bytes());
                let _ = w.finish();
                return;
            }
            StreamEvent::Error(api) => {
                // Mid-stream failure: the 200 head is on the wire, so the
                // error travels as the terminal NDJSON line.
                let _ = w.chunk(format!("{}\n", api.to_json()).as_bytes());
                let _ = w.finish();
                return;
            }
        };
        if w.chunk(format!("{line}\n").as_bytes()).is_err() {
            return; // client hung up; engine keeps going, sends are dropped
        }
        ev = match events.recv() {
            Ok(next) => next,
            Err(_) => {
                let api = ApiError::new(500, "engine_failure", "stream ended without Done");
                let _ = w.chunk(format!("{}\n", api.to_json()).as_bytes());
                let _ = w.finish();
                return;
            }
        };
    }
}

/// Non-streaming mode: wait for Done, answer one JSON document.
fn collect_response(mut stream: TcpStream, first: StreamEvent, events: &Receiver<StreamEvent>) {
    let mut ev = first;
    loop {
        match ev {
            StreamEvent::Token { .. } => {}
            StreamEvent::Done { finished } => {
                let mut body = api::done_line(&finished);
                body.set("n_generated", jnum(finished.generated().len() as f64));
                let _ = http::write_json_response(&mut stream, 200, &[], &body);
                return;
            }
            StreamEvent::Error(api) => {
                let _ = http::write_json_response(&mut stream, api.status, &[], &api.to_json());
                return;
            }
        }
        ev = match events.recv() {
            Ok(next) => next,
            Err(_) => {
                let api = ApiError::new(500, "engine_failure", "stream ended without Done");
                let _ = http::write_json_response(&mut stream, 500, &[], &api.to_json());
                return;
            }
        };
    }
}
