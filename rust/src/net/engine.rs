//! The dedicated batching thread that owns the model.
//!
//! Connection workers never touch the `ModelServer` — they talk to ONE
//! engine thread over an mpsc channel. The engine loop interleaves
//! command intake (submit/metrics/health) with continuous-batching
//! [`DecodeScheduler::step_observed`] calls, forwarding every sampled
//! token to the submitting connection's [`StreamEvent`] channel the
//! moment it exists. This is the decoupling the front-end is built on:
//! slow clients block their own socket, never the batch loop (token
//! sends are non-blocking onto an unbounded per-request channel), and
//! the engine admits across tenants in strict arrival order.
//!
//! With `prefill_chunk > 0` on the [`crate::serve::ServeConfig`], long
//! prompts prefill a fixed-size chunk per engine step instead of
//! monopolizing the step they are admitted in, so streams already in
//! flight keep receiving a token per step while a long prompt warms up;
//! the mid-prefill request's own first `Token { first: true }` arrives
//! when its final chunk commits. Nothing here changes — the scheduler
//! hides the chunking behind the same `step_observed` calls.

use super::api::{classify, ApiError};
use super::drain::DrainState;
use crate::adapter::{AdapterEngine, TierManager};
use crate::serve::{
    DecodeScheduler, FinishedSeq, KvCache, ModelServer, SeqId, SeqRequest, StepObserver,
};
use crate::util::json::{jnum, jstr, Json};
use std::collections::HashMap;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::time::Duration;

/// Per-request stream events, in emission order: zero or more `Token`s
/// then exactly one terminal `Done`/`Error`.
#[derive(Clone, Debug)]
pub enum StreamEvent {
    Token { token: usize, first: bool },
    Done { finished: FinishedSeq },
    Error(ApiError),
}

/// Commands into the engine thread.
pub enum EngineMsg {
    /// Run one generation; every event goes back through `events`.
    Submit { req: SeqRequest, events: Sender<StreamEvent> },
    /// Snapshot `/metrics` (serve stats + residency + queue depths).
    Metrics { reply: Sender<Json> },
    /// Snapshot `/healthz`.
    Health { reply: Sender<Json> },
}

/// How long the idle engine parks in `recv_timeout` before re-checking
/// the drain flag.
const IDLE_WAIT: Duration = Duration::from_millis(20);

/// Adapter residency state owned by a tiered engine thread: the
/// `AdapterEngine` the serving snapshot was taken from (promotion needs
/// its factors; demotion spills them) plus the [`TierManager`] policy.
///
/// The tier hook runs at STEP BOUNDARIES only: before each
/// `step_observed` call the engine promotes every adapter the batch is
/// about to touch (attach-on-miss for cold/warm tenants) and evicts LRU
/// residents past the byte budget. Nothing inside the batched decode
/// loop ever sees a tier transition.
pub struct TierRuntime {
    pub engine: AdapterEngine,
    pub tiers: TierManager,
}

struct EventObserver<'a> {
    streams: &'a mut HashMap<SeqId, Sender<StreamEvent>>,
    rejected: Vec<(SeqId, ApiError)>,
}

impl StepObserver for EventObserver<'_> {
    fn on_token(&mut self, id: SeqId, token: usize, first: bool) {
        if let Some(tx) = self.streams.get(&id) {
            // A hung-up client is its own problem; the batch moves on.
            let _ = tx.send(StreamEvent::Token { token, first });
        }
    }

    fn on_reject(&mut self, id: SeqId, err: &anyhow::Error) {
        self.rejected.push((id, classify(err)));
    }
}

/// Everything the engine thread owns.
struct Engine {
    server: ModelServer,
    cache: KvCache,
    sched: DecodeScheduler,
    streams: HashMap<SeqId, Sender<StreamEvent>>,
    drain: Arc<DrainState>,
    /// `Some` when serving under a residency budget (`start_tiered`).
    tiers: Option<TierRuntime>,
}

impl Engine {
    fn handle(&mut self, msg: EngineMsg) {
        match msg {
            EngineMsg::Submit { req, events } => {
                // The HTTP layer checks the drain flag before submitting,
                // but the race (drain begins while a submit is in the
                // channel) lands here: refuse rather than admit.
                if !self.drain.accepting() {
                    let api = ApiError::new(503, "draining", "server is draining").retry_after(1.0);
                    let _ = events.send(StreamEvent::Error(api));
                    return;
                }
                let id = self.sched.submit(req);
                self.streams.insert(id, events);
            }
            EngineMsg::Metrics { reply } => {
                let _ = reply.send(self.metrics_json());
            }
            EngineMsg::Health { reply } => {
                let _ = reply.send(self.health_json());
            }
        }
    }

    /// Serve stats + residency + live queue depths (+ tier traffic when
    /// serving under a residency budget).
    fn metrics_json(&self) -> Json {
        let mut o = self.server.stats().to_json();
        let mut resident = self.server.resident_breakdown_with_cache(&self.cache);
        if let Some(tr) = &self.tiers {
            resident = resident.with_adapter_tiers(tr.tiers.tier_table());
            let c = tr.tiers.counters();
            let mut t = Json::obj();
            t.set("budget_bytes", jnum(tr.tiers.budget_bytes() as f64));
            t.set("resident_bytes", jnum(tr.tiers.resident_bytes() as f64));
            t.set("promotions", jnum(c.promotions as f64));
            t.set("demotions", jnum(c.demotions as f64));
            t.set("cold_attaches", jnum(c.cold_attaches as f64));
            t.set("over_budget", jnum(c.over_budget as f64));
            t.set("attach_p95_s", jnum(tr.tiers.attach_p95_s()));
            o.set("adapter_tiering", t);
        }
        o.set("resident", resident.to_json());
        o.set("pending_seqs", jnum(self.sched.pending() as f64));
        o.set("running_seqs", jnum(self.sched.running() as f64));
        o
    }

    /// The step-boundary residency hook: fold the serving layer's hit
    /// counters into the LRU clock, then promote everything the pending
    /// and running sequences need (attach-on-miss) and evict past the
    /// budget. Promotion failures are reported per adapter; the affected
    /// requests then draw the scheduler's typed `unknown_adapter`
    /// rejection on the very next step instead of wedging the batch.
    fn ensure_adapters_resident(&mut self) {
        let Some(tr) = self.tiers.as_mut() else { return };
        tr.tiers.sync_hits(&self.server.stats().hits);
        let wanted = self.sched.active_adapters();
        for (name, err) in tr.tiers.ensure_resident(&mut tr.engine, &mut self.server, &wanted) {
            self.server.record_rejection("adapter_promotion_failed");
            eprintln!("[engine] promoting adapter '{name}' failed: {err:#}");
        }
    }

    /// Readiness: engine loop alive + still admitting + KV pages free.
    fn health_json(&self) -> Json {
        let free = self.cache.free_slots();
        let ready = self.drain.accepting() && free > 0;
        let mut o = Json::obj();
        o.set("ready", Json::Bool(ready));
        o.set("phase", jstr(self.drain.phase().name()));
        o.set("slots", jnum(self.cache.slots() as f64));
        o.set("free_slots", jnum(free as f64));
        o.set("kv_reserved_bytes", jnum(self.cache.reserved_bytes() as f64));
        o.set("kv_budget_bytes", jnum(self.cache.budget_bytes() as f64));
        o.set("pending_seqs", jnum(self.sched.pending() as f64));
        o.set("running_seqs", jnum(self.sched.running() as f64));
        o
    }

    /// Flush `f` to its stream as the terminal Done event.
    fn send_done(&mut self, f: FinishedSeq) {
        if let Some(tx) = self.streams.remove(&f.id) {
            let _ = tx.send(StreamEvent::Done { finished: f });
        }
    }
}

/// The engine loop. Runs until the command channel disconnects or a
/// drain completes (drain begun + nothing pending or running), then
/// flushes buffered retirements — zero lost streams — and marks the
/// drain state stopped.
pub fn run_engine(
    server: ModelServer,
    cache: KvCache,
    rx: Receiver<EngineMsg>,
    drain: Arc<DrainState>,
    tiers: Option<TierRuntime>,
) {
    let mut eng = Engine {
        server,
        cache,
        sched: DecodeScheduler::new(),
        streams: HashMap::new(),
        drain,
        tiers,
    };
    let mut disconnected = false;
    loop {
        // Intake: everything queued right now, without blocking.
        loop {
            match rx.try_recv() {
                Ok(msg) => eng.handle(msg),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }

        if eng.sched.idle() {
            if disconnected || !eng.drain.accepting() {
                break;
            }
            // Nothing to decode: park until a command arrives.
            match rx.recv_timeout(IDLE_WAIT) {
                Ok(msg) => eng.handle(msg),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => disconnected = true,
            }
            continue;
        }

        // Residency first (promote misses, evict past budget), OUTSIDE
        // the batched step — then one continuous-batching step with
        // tokens streaming out mid-step.
        eng.ensure_adapters_resident();
        let mut obs = EventObserver { streams: &mut eng.streams, rejected: Vec::new() };
        let result = eng.sched.step_observed(&mut eng.server, &mut eng.cache, &mut obs);
        let rejected = std::mem::take(&mut obs.rejected);
        for (id, api) in rejected {
            eng.server.record_rejection(api.code);
            if let Some(tx) = eng.streams.remove(&id) {
                let _ = tx.send(StreamEvent::Error(api));
            }
        }
        match result {
            Ok(finished) => {
                for f in finished {
                    eng.send_done(f);
                }
            }
            Err(e) => {
                // A step-level failure poisons every in-flight sequence:
                // tell each client, then stop serving.
                let api = ApiError::new(500, "engine_failure", format!("{e:#}"));
                for f in eng.sched.drain_finished() {
                    eng.send_done(f);
                }
                for (_, tx) in eng.streams.drain() {
                    let _ = tx.send(StreamEvent::Error(api.clone()));
                }
                break;
            }
        }
    }
    // Retirements buffered by an errored step still reach their clients.
    for f in eng.sched.drain_finished() {
        eng.send_done(f);
    }
    for (_, tx) in eng.streams.drain() {
        let _ = tx.send(StreamEvent::Error(ApiError::new(
            503,
            "stopped",
            "server stopped before this sequence completed",
        )));
    }
    eng.drain.mark_engine_stopped();
}
