//! Graceful-drain lifecycle for the HTTP front-end.
//!
//! A serving process moves through three phases: `Running` (admitting
//! new work), `Draining` (new requests are rejected with 503 while
//! everything already admitted runs to completion and its stream is
//! flushed), and `Stopped` (the engine thread has exited). The phase
//! lives in one [`DrainState`] shared by the listener, every connection
//! worker, the engine thread, and the optional SIGTERM/SIGINT watcher —
//! a single atomic so a phase check never takes a lock on the hot path.

use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Server lifecycle phase.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Admitting new requests.
    Running,
    /// Rejecting new requests (503 + `Retry-After`); in-flight sequences
    /// run to completion and their streams are flushed.
    Draining,
    /// The engine thread has exited; nothing is in flight.
    Stopped,
}

impl Phase {
    pub fn name(&self) -> &'static str {
        match self {
            Phase::Running => "running",
            Phase::Draining => "draining",
            Phase::Stopped => "stopped",
        }
    }
}

const RUNNING: u8 = 0;
const DRAINING: u8 = 1;
const STOPPED: u8 = 2;

/// Shared drain coordination: the phase atomic plus a condvar the engine
/// thread signals when it exits (so `shutdown` can wait without
/// spinning).
#[derive(Debug)]
pub struct DrainState {
    phase: AtomicU8,
    engine_stopped: Mutex<bool>,
    cv: Condvar,
}

impl Default for DrainState {
    fn default() -> Self {
        DrainState::new()
    }
}

impl DrainState {
    pub fn new() -> DrainState {
        DrainState {
            phase: AtomicU8::new(RUNNING),
            engine_stopped: Mutex::new(false),
            cv: Condvar::new(),
        }
    }

    pub fn phase(&self) -> Phase {
        match self.phase.load(Ordering::Acquire) {
            RUNNING => Phase::Running,
            DRAINING => Phase::Draining,
            _ => Phase::Stopped,
        }
    }

    /// Still admitting new requests?
    pub fn accepting(&self) -> bool {
        self.phase.load(Ordering::Acquire) == RUNNING
    }

    /// Move `Running` → `Draining`. Returns `true` if THIS call made the
    /// transition (idempotent: later calls and calls after `Stopped` are
    /// no-ops returning `false`).
    pub fn begin_drain(&self) -> bool {
        self.phase
            .compare_exchange(RUNNING, DRAINING, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
    }

    /// The engine thread announces it has exited: phase becomes
    /// `Stopped` and every `wait_engine_stopped` waiter wakes.
    pub fn mark_engine_stopped(&self) {
        self.phase.store(STOPPED, Ordering::Release);
        let mut stopped = self.engine_stopped.lock().expect("drain lock");
        *stopped = true;
        self.cv.notify_all();
    }

    /// Block until the engine thread has exited (drain complete).
    pub fn wait_engine_stopped(&self) {
        let mut stopped = self.engine_stopped.lock().expect("drain lock");
        while !*stopped {
            stopped = self.cv.wait(stopped).expect("drain lock");
        }
    }
}

/// Process-global "a termination signal arrived" flag, set by the raw
/// signal handler below (a handler can do nothing more elaborate than a
/// relaxed atomic store).
static SIGNALED: AtomicBool = AtomicBool::new(false);

/// Has SIGTERM/SIGINT been delivered since [`install_signal_handlers`]?
pub fn termination_signaled() -> bool {
    SIGNALED.load(Ordering::Relaxed)
}

#[cfg(unix)]
mod sys {
    use super::SIGNALED;
    use std::sync::atomic::Ordering;

    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;

    extern "C" {
        // POSIX signal(2); the libc crate is not in the vendor set, so
        // declare the single symbol we need.
        fn signal(signum: i32, handler: usize) -> usize;
    }

    extern "C" fn on_signal(_sig: i32) {
        SIGNALED.store(true, Ordering::Relaxed);
    }

    pub fn install() {
        let h = on_signal as extern "C" fn(i32) as usize;
        unsafe {
            signal(SIGTERM, h);
            signal(SIGINT, h);
        }
    }
}

#[cfg(not(unix))]
mod sys {
    pub fn install() {}
}

/// Route SIGTERM/SIGINT into [`termination_signaled`] (no-op off unix).
/// The server pairs this with a watcher thread that polls the flag and
/// calls [`DrainState::begin_drain`] — the handler itself only flips an
/// atomic, which is all that is async-signal-safe.
pub fn install_signal_handlers() {
    sys::install()
}

/// Spawn the watcher thread: poll [`termination_signaled`] and begin the
/// drain the moment it fires. Exits once the drain has started (for any
/// reason, signal or programmatic).
pub fn spawn_signal_watcher(drain: Arc<DrainState>) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || loop {
        if termination_signaled() {
            drain.begin_drain();
            return;
        }
        if drain.phase() != Phase::Running {
            return;
        }
        std::thread::sleep(std::time::Duration::from_millis(25));
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_transitions_are_one_way_and_idempotent() {
        let d = DrainState::new();
        assert_eq!(d.phase(), Phase::Running);
        assert!(d.accepting());
        assert!(d.begin_drain());
        assert_eq!(d.phase(), Phase::Draining);
        assert!(!d.accepting());
        // Second drain call is a no-op.
        assert!(!d.begin_drain());
        d.mark_engine_stopped();
        assert_eq!(d.phase(), Phase::Stopped);
        // Draining after stop does not resurrect the server.
        assert!(!d.begin_drain());
        assert_eq!(d.phase(), Phase::Stopped);
        assert_eq!(Phase::Stopped.name(), "stopped");
    }

    #[test]
    fn wait_engine_stopped_wakes_on_mark() {
        let d = Arc::new(DrainState::new());
        let d2 = Arc::clone(&d);
        let waiter = std::thread::spawn(move || d2.wait_engine_stopped());
        std::thread::sleep(std::time::Duration::from_millis(10));
        d.mark_engine_stopped();
        waiter.join().expect("waiter thread");
        // Waiting after the fact returns immediately.
        d.wait_engine_stopped();
    }
}
