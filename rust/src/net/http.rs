//! Dependency-free HTTP/1.1 plumbing over std TCP streams.
//!
//! Exactly what the front-end needs and nothing more: blocking
//! request parsing with size limits (header block and body, including
//! chunked request bodies — extensions stripped, every declared chunk
//! size bounded before allocation), fixed `Content-Length` JSON
//! responses, chunked transfer-encoding for token streaming, and a tiny
//! loopback client (used by the tests and the load-test bench). Every connection is `Connection: close` — one
//! request per TCP stream keeps worker lifecycle and drain accounting
//! trivial, and the loopback benchmarks show connection setup is noise
//! next to decode time.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;

/// Upper bound on the request line + headers block.
pub const MAX_HEADER_BYTES: usize = 16 * 1024;

/// One parsed request. Header names are lower-cased.
#[derive(Clone, Debug)]
pub struct HttpRequest {
    pub method: String,
    pub target: String,
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

/// A request-parse failure, mapped straight to a status code.
#[derive(Clone, Debug, PartialEq)]
pub struct HttpParseError {
    pub status: u16,
    pub message: String,
}

impl HttpParseError {
    fn new(status: u16, message: impl Into<String>) -> HttpParseError {
        HttpParseError { status, message: message.into() }
    }
}

/// Upper bound on a single transfer-encoding chunk accepted by the
/// loopback CLIENT readers ([`read_response`] /
/// [`StreamingClient::next_chunk`]); the server side bounds chunks by
/// its `max_body` instead. A declared size is validated against the
/// bound BEFORE the buffer for it is allocated — a hostile
/// `ffffffffffffffff\r\n` size line is an error, not an OOM.
pub const MAX_CHUNK_BYTES: usize = 1 << 20;

/// Parse one RFC 7230 chunk-size line: hex size, optionally followed by
/// `;`-separated chunk extensions (`1a;ext=v`), which are ignored.
/// Errors on malformed hex or a size above `cap`.
pub fn parse_chunk_size(size_line: &str, cap: usize) -> Result<usize, String> {
    let line = size_line.trim();
    // Extensions (and any padding around the size) are legal; only the
    // leading hex field matters.
    let size = line.split(';').next().unwrap_or("").trim();
    let n = usize::from_str_radix(size, 16).map_err(|_| format!("bad chunk size '{line}'"))?;
    if n > cap {
        return Err(format!("chunk of {n} bytes exceeds limit {cap}"));
    }
    Ok(n)
}

/// Canonical reason phrase for the statuses the server emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        422 => "Unprocessable Entity",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Read one request from `reader`. `max_body` bounds the declared
/// `Content-Length`; anything larger is a 413 without reading the body.
pub fn read_request<R: BufRead>(
    reader: &mut R,
    max_body: usize,
) -> Result<HttpRequest, HttpParseError> {
    let mut head_bytes = 0usize;
    let mut line = String::new();
    reader
        .read_line(&mut line)
        .map_err(|e| HttpParseError::new(400, format!("read request line: {e}")))?;
    if line.is_empty() {
        return Err(HttpParseError::new(400, "empty request"));
    }
    head_bytes += line.len();
    let mut parts = line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| HttpParseError::new(400, "missing method"))?
        .to_string();
    let target = parts
        .next()
        .ok_or_else(|| HttpParseError::new(400, "missing request target"))?
        .to_string();
    let version = parts.next().unwrap_or("");
    if !version.starts_with("HTTP/1.") {
        return Err(HttpParseError::new(400, format!("unsupported version '{version}'")));
    }
    let mut headers = BTreeMap::new();
    loop {
        let mut h = String::new();
        reader
            .read_line(&mut h)
            .map_err(|e| HttpParseError::new(400, format!("read header: {e}")))?;
        head_bytes += h.len();
        if head_bytes > MAX_HEADER_BYTES {
            return Err(HttpParseError::new(413, "header block too large"));
        }
        let t = h.trim_end_matches(['\r', '\n']);
        if t.is_empty() {
            break;
        }
        if let Some((k, v)) = t.split_once(':') {
            headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
        } else {
            return Err(HttpParseError::new(400, format!("malformed header '{t}'")));
        }
    }
    let chunked = headers
        .get("transfer-encoding")
        .map(|v| v.eq_ignore_ascii_case("chunked"))
        .unwrap_or(false);
    let body = if chunked {
        read_chunked_body(reader, max_body)?
    } else {
        match headers.get("content-length") {
            Some(v) => {
                let n: usize = v
                    .parse()
                    .map_err(|_| HttpParseError::new(400, format!("bad content-length '{v}'")))?;
                if n > max_body {
                    return Err(HttpParseError::new(
                        413,
                        format!("body of {n} bytes exceeds limit {max_body}"),
                    ));
                }
                let mut buf = vec![0u8; n];
                reader
                    .read_exact(&mut buf)
                    .map_err(|e| HttpParseError::new(400, format!("read body: {e}")))?;
                buf
            }
            None => Vec::new(),
        }
    };
    Ok(HttpRequest { method, target, headers, body })
}

/// De-chunk a `Transfer-Encoding: chunked` request body. Chunk
/// extensions (`1a;ext=v`) parse per RFC 7230; every declared size is
/// checked against what `max_body` still allows BEFORE its buffer is
/// allocated, so an oversized declaration is a 413, never an OOM.
fn read_chunked_body<R: BufRead>(
    reader: &mut R,
    max_body: usize,
) -> Result<Vec<u8>, HttpParseError> {
    let mut body = Vec::new();
    loop {
        let mut size_line = String::new();
        reader
            .read_line(&mut size_line)
            .map_err(|e| HttpParseError::new(400, format!("read chunk size: {e}")))?;
        let remaining = max_body - body.len();
        let n = parse_chunk_size(&size_line, remaining).map_err(|m| {
            let status = if m.starts_with("bad chunk size") { 400 } else { 413 };
            HttpParseError::new(status, m)
        })?;
        if n == 0 {
            // Trailer section: skip until the blank line ending the body.
            loop {
                let mut t = String::new();
                reader
                    .read_line(&mut t)
                    .map_err(|e| HttpParseError::new(400, format!("read trailer: {e}")))?;
                if t.trim_end_matches(['\r', '\n']).is_empty() {
                    break;
                }
            }
            return Ok(body);
        }
        let mut chunk = vec![0u8; n + 2]; // data + trailing CRLF
        reader
            .read_exact(&mut chunk)
            .map_err(|e| HttpParseError::new(400, format!("read chunk: {e}")))?;
        chunk.truncate(n);
        body.append(&mut chunk);
    }
}

/// Write a complete JSON response with `Content-Length` and close
/// semantics. `extra_headers` are (name, value) pairs appended verbatim
/// (e.g. `("Retry-After", "2")`).
pub fn write_json_response(
    stream: &mut impl Write,
    status: u16,
    extra_headers: &[(String, String)],
    body: &Json,
) -> std::io::Result<()> {
    let text = format!("{body}\n");
    let mut head = format!(
        "HTTP/1.1 {status} {}\r\ncontent-type: application/json\r\ncontent-length: {}\r\nconnection: close\r\n",
        reason(status),
        text.len()
    );
    for (k, v) in extra_headers {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    stream.write_all(text.as_bytes())?;
    stream.flush()
}

/// Chunked transfer-encoding writer for token streaming. Every
/// [`ChunkedWriter::chunk`] is flushed immediately — the whole point is
/// that the client sees each token as `decode_step` produces it.
pub struct ChunkedWriter<W: Write> {
    stream: W,
}

impl<W: Write> ChunkedWriter<W> {
    /// Write the response head and enter chunked mode.
    pub fn start(
        mut stream: W,
        status: u16,
        extra_headers: &[(String, String)],
    ) -> std::io::Result<ChunkedWriter<W>> {
        let mut head = format!(
            "HTTP/1.1 {status} {}\r\ncontent-type: application/x-ndjson\r\ntransfer-encoding: chunked\r\nconnection: close\r\n",
            reason(status)
        );
        for (k, v) in extra_headers {
            head.push_str(&format!("{k}: {v}\r\n"));
        }
        head.push_str("\r\n");
        stream.write_all(head.as_bytes())?;
        stream.flush()?;
        Ok(ChunkedWriter { stream })
    }

    /// One chunk: `{len:x}\r\n{data}\r\n`, flushed.
    pub fn chunk(&mut self, data: &[u8]) -> std::io::Result<()> {
        if data.is_empty() {
            return Ok(()); // an empty chunk would terminate the stream
        }
        write!(self.stream, "{:x}\r\n", data.len())?;
        self.stream.write_all(data)?;
        self.stream.write_all(b"\r\n")?;
        self.stream.flush()
    }

    /// Terminating zero chunk.
    pub fn finish(mut self) -> std::io::Result<()> {
        self.stream.write_all(b"0\r\n\r\n")?;
        self.stream.flush()
    }
}

/// A fully buffered response from the loopback client.
#[derive(Clone, Debug)]
pub struct HttpResponse {
    pub status: u16,
    pub headers: BTreeMap<String, String>,
    /// Decoded body (chunked responses are de-chunked).
    pub body: Vec<u8>,
}

impl HttpResponse {
    pub fn body_str(&self) -> &str {
        std::str::from_utf8(&self.body).unwrap_or("")
    }

    pub fn json(&self) -> anyhow::Result<Json> {
        Json::parse(self.body_str().trim())
    }

    /// Parse an NDJSON body (one JSON document per line).
    pub fn json_lines(&self) -> anyhow::Result<Vec<Json>> {
        self.body_str()
            .lines()
            .filter(|l| !l.trim().is_empty())
            .map(Json::parse)
            .collect()
    }

    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.get(&name.to_ascii_lowercase()).map(|s| s.as_str())
    }
}

/// Read a full response (status line, headers, body — de-chunking if
/// needed) from `reader`.
pub fn read_response<R: BufRead>(reader: &mut R) -> anyhow::Result<HttpResponse> {
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .ok_or_else(|| anyhow::anyhow!("malformed status line '{line}'"))?
        .parse()?;
    let mut headers = BTreeMap::new();
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let t = h.trim_end_matches(['\r', '\n']);
        if t.is_empty() {
            break;
        }
        if let Some((k, v)) = t.split_once(':') {
            headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
        }
    }
    let mut body = Vec::new();
    if headers.get("transfer-encoding").map(|v| v == "chunked").unwrap_or(false) {
        loop {
            let mut size_line = String::new();
            reader.read_line(&mut size_line)?;
            let n = parse_chunk_size(&size_line, MAX_CHUNK_BYTES)
                .map_err(|m| anyhow::anyhow!("{m}"))?;
            if n == 0 {
                let mut crlf = String::new();
                reader.read_line(&mut crlf)?;
                break;
            }
            let mut chunk = vec![0u8; n + 2]; // data + trailing CRLF
            reader.read_exact(&mut chunk)?;
            chunk.truncate(n);
            body.extend_from_slice(&chunk);
        }
    } else if let Some(v) = headers.get("content-length") {
        let n: usize = v.parse()?;
        let mut buf = vec![0u8; n];
        reader.read_exact(&mut buf)?;
        body = buf;
    } else {
        reader.read_to_end(&mut body)?;
    }
    Ok(HttpResponse { status, headers, body })
}

/// One-shot loopback client: connect, send, read the whole response.
pub fn request(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&Json>,
) -> anyhow::Result<HttpResponse> {
    let mut stream = TcpStream::connect(addr)?;
    let payload = body.map(|j| format!("{j}\n")).unwrap_or_default();
    let head = format!(
        "{method} {path} HTTP/1.1\r\nhost: {addr}\r\ncontent-length: {}\r\nconnection: close\r\n\r\n",
        payload.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(payload.as_bytes())?;
    stream.flush()?;
    let mut reader = BufReader::new(stream);
    read_response(&mut reader)
}

/// Streaming loopback client: sends the request, exposes the response
/// head immediately, then yields chunks one at a time — so a test can
/// measure time-to-first-chunk and observe tokens arriving before the
/// generation finishes.
pub struct StreamingClient {
    reader: BufReader<TcpStream>,
    pub status: u16,
    pub headers: BTreeMap<String, String>,
    chunked: bool,
    done: bool,
}

impl StreamingClient {
    pub fn post(addr: &str, path: &str, body: &Json) -> anyhow::Result<StreamingClient> {
        let mut stream = TcpStream::connect(addr)?;
        let payload = format!("{body}\n");
        let head = format!(
            "POST {path} HTTP/1.1\r\nhost: {addr}\r\ncontent-length: {}\r\nconnection: close\r\n\r\n",
            payload.len()
        );
        stream.write_all(head.as_bytes())?;
        stream.write_all(payload.as_bytes())?;
        stream.flush()?;
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        reader.read_line(&mut line)?;
        let status: u16 = line
            .split_whitespace()
            .nth(1)
            .ok_or_else(|| anyhow::anyhow!("malformed status line '{line}'"))?
            .parse()?;
        let mut headers = BTreeMap::new();
        loop {
            let mut h = String::new();
            reader.read_line(&mut h)?;
            let t = h.trim_end_matches(['\r', '\n']);
            if t.is_empty() {
                break;
            }
            if let Some((k, v)) = t.split_once(':') {
                headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
            }
        }
        let chunked =
            headers.get("transfer-encoding").map(|v| v == "chunked").unwrap_or(false);
        Ok(StreamingClient { reader, status, headers, chunked, done: false })
    }

    /// Next chunk of the chunked body (`None` once the stream ends).
    /// For non-chunked responses, returns the whole body once.
    pub fn next_chunk(&mut self) -> anyhow::Result<Option<Vec<u8>>> {
        if self.done {
            return Ok(None);
        }
        if !self.chunked {
            self.done = true;
            let mut body = Vec::new();
            if let Some(v) = self.headers.get("content-length") {
                body = vec![0u8; v.parse()?];
                self.reader.read_exact(&mut body)?;
            } else {
                self.reader.read_to_end(&mut body)?;
            }
            return Ok(Some(body));
        }
        let mut size_line = String::new();
        self.reader.read_line(&mut size_line)?;
        let n = parse_chunk_size(&size_line, MAX_CHUNK_BYTES)
            .map_err(|m| anyhow::anyhow!("{m}"))?;
        if n == 0 {
            self.done = true;
            let mut crlf = String::new();
            self.reader.read_line(&mut crlf)?;
            return Ok(None);
        }
        let mut chunk = vec![0u8; n + 2];
        self.reader.read_exact(&mut chunk)?;
        chunk.truncate(n);
        Ok(Some(chunk))
    }

    /// Drain the remaining chunks into one buffer.
    pub fn read_rest(&mut self) -> anyhow::Result<Vec<u8>> {
        let mut out = Vec::new();
        while let Some(c) = self.next_chunk()? {
            out.extend_from_slice(&c);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_a_post_with_body() {
        let raw = b"POST /v1/generate HTTP/1.1\r\nHost: x\r\nContent-Length: 4\r\n\r\nabcd";
        let mut r = BufReader::new(Cursor::new(&raw[..]));
        let req = read_request(&mut r, 1024).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.target, "/v1/generate");
        assert_eq!(req.headers.get("host").map(|s| s.as_str()), Some("x"));
        assert_eq!(req.body, b"abcd");
    }

    #[test]
    fn rejects_oversized_and_malformed_requests() {
        let over = b"POST / HTTP/1.1\r\nContent-Length: 999\r\n\r\n";
        let mut r = BufReader::new(Cursor::new(&over[..]));
        assert_eq!(read_request(&mut r, 100).unwrap_err().status, 413);

        let badver = b"GET / SPDY/9\r\n\r\n";
        let mut r = BufReader::new(Cursor::new(&badver[..]));
        assert_eq!(read_request(&mut r, 100).unwrap_err().status, 400);

        let badlen = b"POST / HTTP/1.1\r\nContent-Length: ten\r\n\r\n";
        let mut r = BufReader::new(Cursor::new(&badlen[..]));
        assert_eq!(read_request(&mut r, 100).unwrap_err().status, 400);

        let noheader = b"GET / HTTP/1.1\r\nnot-a-header\r\n\r\n";
        let mut r = BufReader::new(Cursor::new(&noheader[..]));
        assert_eq!(read_request(&mut r, 100).unwrap_err().status, 400);

        // Truncated body: declared 10 bytes, stream has 2.
        let short = b"POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nab";
        let mut r = BufReader::new(Cursor::new(&short[..]));
        assert_eq!(read_request(&mut r, 100).unwrap_err().status, 400);

        let mut r = BufReader::new(Cursor::new(&b""[..]));
        assert!(read_request(&mut r, 100).is_err());
    }

    #[test]
    fn giant_header_block_is_413() {
        let mut raw = b"GET / HTTP/1.1\r\n".to_vec();
        for i in 0..2000 {
            raw.extend_from_slice(format!("x-h{i}: {}\r\n", "v".repeat(64)).as_bytes());
        }
        raw.extend_from_slice(b"\r\n");
        let mut r = BufReader::new(Cursor::new(raw));
        assert_eq!(read_request(&mut r, 100).unwrap_err().status, 413);
    }

    #[test]
    fn json_response_roundtrips_through_read_response() {
        let mut j = Json::obj();
        j.set("ok", Json::Bool(true));
        let mut wire = Vec::new();
        write_json_response(&mut wire, 200, &[("retry-after".into(), "2".into())], &j).unwrap();
        let mut r = BufReader::new(Cursor::new(wire));
        let resp = read_response(&mut r).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.header("retry-after"), Some("2"));
        assert_eq!(resp.json().unwrap().get("ok"), Some(&Json::Bool(true)));
    }

    #[test]
    fn chunked_writer_roundtrips_and_dechunks() {
        let mut wire = Vec::new();
        {
            let mut w = ChunkedWriter::start(&mut wire, 200, &[]).unwrap();
            w.chunk(b"{\"token\":1}\n").unwrap();
            w.chunk(b"").unwrap(); // no-op, must not terminate early
            w.chunk(b"{\"token\":2}\n").unwrap();
            w.finish().unwrap();
        }
        let mut r = BufReader::new(Cursor::new(wire));
        let resp = read_response(&mut r).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.header("transfer-encoding"), Some("chunked"));
        let lines = resp.json_lines().unwrap();
        assert_eq!(lines.len(), 2);
        assert_eq!(lines[1].get("token").and_then(|v| v.as_f64()), Some(2.0));
    }

    #[test]
    fn chunk_size_lines_accept_extensions_and_bound_the_size() {
        // RFC 7230 chunk extensions are ignored, not a parse error.
        assert_eq!(parse_chunk_size("1a;ext=v\r\n", 1024).unwrap(), 0x1a);
        assert_eq!(parse_chunk_size("A; x=\"y\"; z\r\n", 1024).unwrap(), 10);
        assert_eq!(parse_chunk_size("0\r\n", 1024).unwrap(), 0);
        assert!(parse_chunk_size("zz\r\n", 1024).is_err());
        assert!(parse_chunk_size(";ext\r\n", 1024).is_err());
        // The declared size is checked against the cap BEFORE any
        // allocation — a hostile 2^64-ish declaration is an error.
        assert!(parse_chunk_size("ffffffffffffffff\r\n", 1024).is_err());
        assert!(parse_chunk_size("401\r\n", 1024).is_err());
        assert_eq!(parse_chunk_size("400\r\n", 1024).unwrap(), 1024);
    }

    #[test]
    fn chunked_request_bodies_dechunk_with_extensions() {
        let raw = b"POST /v1/generate HTTP/1.1\r\nHost: x\r\n\
                    Transfer-Encoding: chunked\r\n\r\n\
                    4;ext=v\r\nabcd\r\n3\r\nefg\r\n0\r\n\r\n";
        let mut r = BufReader::new(Cursor::new(&raw[..]));
        let req = read_request(&mut r, 1024).unwrap();
        assert_eq!(req.body, b"abcdefg");
    }

    #[test]
    fn oversized_chunk_declaration_is_413_not_oom() {
        // Declares a ~72 PB chunk; must fail on the declaration, never
        // allocating for it.
        let raw = b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n\
                    ffffffffffffff\r\n";
        let mut r = BufReader::new(Cursor::new(&raw[..]));
        assert_eq!(read_request(&mut r, 1024).unwrap_err().status, 413);
        // Cumulative chunks beyond max_body are also a 413.
        let raw = b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n\
                    4\r\nabcd\r\n4\r\nefgh\r\n0\r\n\r\n";
        let mut r = BufReader::new(Cursor::new(&raw[..]));
        assert_eq!(read_request(&mut r, 6).unwrap_err().status, 413);
        // A malformed size line is a 400.
        let raw = b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\nzz\r\n";
        let mut r = BufReader::new(Cursor::new(&raw[..]));
        assert_eq!(read_request(&mut r, 1024).unwrap_err().status, 400);
    }

    #[test]
    fn client_readers_accept_chunk_extensions() {
        let wire = b"HTTP/1.1 200 OK\r\ntransfer-encoding: chunked\r\n\r\n\
                     5;note=x\r\nhello\r\n0\r\n\r\n";
        let mut r = BufReader::new(Cursor::new(&wire[..]));
        let resp = read_response(&mut r).unwrap();
        assert_eq!(resp.body, b"hello");
        // And reject an over-cap declaration instead of allocating it.
        let wire = format!(
            "HTTP/1.1 200 OK\r\ntransfer-encoding: chunked\r\n\r\n{:x}\r\n",
            MAX_CHUNK_BYTES + 1
        );
        let mut r = BufReader::new(Cursor::new(wire.into_bytes()));
        assert!(read_response(&mut r).is_err());
    }

    #[test]
    fn reason_phrases_cover_the_emitted_statuses() {
        for s in [200, 400, 404, 405, 413, 422, 429, 500, 503] {
            assert_ne!(reason(s), "Unknown", "status {s}");
        }
        assert_eq!(reason(599), "Unknown");
    }
}
