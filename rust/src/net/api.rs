//! The wire API: typed request parsing/validation and response shapes.
//!
//! One endpoint does the work — `POST /v1/generate` with a JSON body:
//!
//! ```json
//! {"adapter": "tenant00", "prompt": [1, 2, 3], "max_new": 16,
//!  "stop": 7, "stream": true}
//! ```
//!
//! `adapter` omitted/null targets the frozen base. Streaming responses
//! are NDJSON over chunked transfer-encoding: a meta line, one line per
//! token (`{"first":true,"token":5}`), and a final done line carrying
//! the whole trajectory. Non-streaming responses return the done object
//! alone. Errors are always `{"error":{"code":...,"message":...}}` with
//! the status code mirroring [`ServeError::http_status`].

use crate::adapter::AdapterError;
use crate::serve::{FinishReason, FinishedSeq, ServeError};
use crate::util::json::{jarr, jnum, jstr, Json};
use std::collections::BTreeSet;

/// Upper bound on any advertised retry delay. A tenant with
/// `rate_per_s: 0.0` has an INFINITE token-refill ETA; without a cap
/// that used to reach the `Retry-After` header as
/// `f64::INFINITY.ceil() as u64` = 18446744073709551615. Anything
/// non-finite or beyond this cap is reported as the cap instead.
pub const MAX_RETRY_AFTER_S: f64 = 60.0;

/// A typed wire-level error: HTTP status + machine-readable code.
#[derive(Clone, Debug, PartialEq)]
pub struct ApiError {
    pub status: u16,
    pub code: &'static str,
    pub message: String,
    /// Seconds the client should wait before retrying (429/503 only).
    /// Always finite, in `[0, MAX_RETRY_AFTER_S]` — the setter clamps.
    pub retry_after_s: Option<f64>,
}

impl ApiError {
    pub fn new(status: u16, code: &'static str, message: impl Into<String>) -> ApiError {
        ApiError { status, code, message: message.into(), retry_after_s: None }
    }

    pub fn retry_after(mut self, secs: f64) -> ApiError {
        let secs =
            if secs.is_finite() { secs.clamp(0.0, MAX_RETRY_AFTER_S) } else { MAX_RETRY_AFTER_S };
        self.retry_after_s = Some(secs);
        self
    }

    /// The `Retry-After` header derived from THE SAME clamped value the
    /// JSON body reports (empty when no retry hint is set) — the single
    /// place body and header are kept in sync. Sub-second hints round up
    /// to the header's 1-second floor.
    pub fn retry_after_header(&self) -> Vec<(String, String)> {
        match self.retry_after_s {
            Some(s) => {
                vec![("retry-after".to_string(), format!("{}", s.ceil().max(1.0) as u64))]
            }
            None => Vec::new(),
        }
    }

    /// The response body: `{"error":{"code":...,"message":...}}`.
    pub fn to_json(&self) -> Json {
        let mut e = Json::obj();
        e.set("code", jstr(self.code));
        e.set("message", jstr(&self.message));
        if let Some(s) = self.retry_after_s {
            e.set("retry_after_s", jnum(s));
        }
        let mut o = Json::obj();
        o.set("error", e);
        o
    }
}

/// Map an engine-side failure to the wire. [`ServeError`]s and
/// [`AdapterError`]s keep their typed status/code; anything else (empty
/// prompt, admission context) is classified by message, defaulting to a
/// 400.
pub fn classify(err: &anyhow::Error) -> ApiError {
    if let Some(se) = err.downcast_ref::<ServeError>() {
        let mut api = ApiError::new(se.http_status(), se.code(), se.to_string());
        if api.status == 503 {
            api = api.retry_after(1.0);
        }
        return api;
    }
    if let Some(ae) = err.downcast_ref::<AdapterError>() {
        return ApiError::new(ae.http_status(), ae.code(), ae.to_string());
    }
    let msg = format!("{err:#}");
    if msg.contains("empty prompt") {
        ApiError::new(422, "empty_prompt", msg)
    } else {
        ApiError::new(400, "bad_request", msg)
    }
}

/// What the validator needs to know about the engine. `adapters` is the
/// full ROUTABLE tenant set — under residency tiering that includes
/// warm/cold names that are not currently attached (they are promoted
/// on miss at the next step boundary), so the wire only 404s names that
/// were never registered at all.
#[derive(Clone, Debug)]
pub struct ApiContext {
    pub vocab: usize,
    pub max_seq: usize,
    pub adapters: BTreeSet<String>,
}

/// A validated `/v1/generate` request.
#[derive(Clone, Debug, PartialEq)]
pub struct GenerateRequest {
    pub adapter: Option<String>,
    pub prompt: Vec<usize>,
    pub max_new: usize,
    pub stop_token: Option<usize>,
    pub stream: bool,
}

/// Default `max_new` when the body omits it.
pub const DEFAULT_MAX_NEW: usize = 16;

/// Parse + validate a `/v1/generate` body against the engine's shape.
/// Every rejection is a typed [`ApiError`] — the caller turns it into
/// the response verbatim.
pub fn parse_generate(body: &[u8], ctx: &ApiContext) -> Result<GenerateRequest, ApiError> {
    let text = std::str::from_utf8(body)
        .map_err(|e| ApiError::new(400, "bad_json", format!("body is not UTF-8: {e}")))?;
    let j = Json::parse(text)
        .map_err(|e| ApiError::new(400, "bad_json", format!("body is not valid JSON: {e}")))?;
    if j.as_obj().is_none() {
        return Err(ApiError::new(400, "bad_json", "body must be a JSON object"));
    }

    let adapter = match j.get("adapter") {
        None | Some(Json::Null) => None,
        Some(Json::Str(s)) => Some(s.clone()),
        Some(_) => {
            return Err(ApiError::new(400, "bad_request", "'adapter' must be a string or null"))
        }
    };
    if let Some(name) = &adapter {
        if !ctx.adapters.contains(name) {
            return Err(ApiError::new(
                404,
                "unknown_adapter",
                format!("no adapter named '{name}' (have: {:?})", ctx.adapters),
            ));
        }
    }

    let prompt_j = j
        .get("prompt")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| ApiError::new(400, "bad_request", "'prompt' must be an array of ints"))?;
    if prompt_j.is_empty() {
        return Err(ApiError::new(422, "empty_prompt", "a generation needs >= 1 prompt token"));
    }
    let mut prompt = Vec::with_capacity(prompt_j.len());
    for (i, v) in prompt_j.iter().enumerate() {
        let n = v.as_f64().ok_or_else(|| {
            ApiError::new(400, "bad_request", format!("prompt[{i}] is not a number"))
        })?;
        if n.fract() != 0.0 || n < 0.0 {
            return Err(ApiError::new(
                400,
                "bad_request",
                format!("prompt[{i}] = {n} is not a nonnegative integer"),
            ));
        }
        let t = n as usize;
        if t >= ctx.vocab {
            return Err(ApiError::new(
                422,
                "token_out_of_range",
                format!("prompt[{i}] = {t} out of range (vocab = {})", ctx.vocab),
            ));
        }
        prompt.push(t);
    }

    let max_new = match j.get("max_new") {
        None => DEFAULT_MAX_NEW,
        Some(v) => {
            let n = v.as_f64().filter(|n| n.fract() == 0.0 && *n >= 0.0).ok_or_else(|| {
                ApiError::new(400, "bad_request", "'max_new' must be a nonnegative integer")
            })?;
            n as usize
        }
    };
    if prompt.len() + max_new > ctx.max_seq {
        return Err(ApiError::new(
            422,
            "seq_too_long",
            format!(
                "{} prompt + {max_new} max_new exceeds max_seq = {}",
                prompt.len(),
                ctx.max_seq
            ),
        ));
    }

    let stop_token = match j.get("stop") {
        None | Some(Json::Null) => None,
        Some(v) => Some(
            v.as_f64()
                .filter(|n| n.fract() == 0.0 && *n >= 0.0 && (*n as usize) < ctx.vocab)
                .map(|n| n as usize)
                .ok_or_else(|| {
                    ApiError::new(400, "bad_request", "'stop' must be an in-vocab token id")
                })?,
        ),
    };

    let stream = match j.get("stream") {
        None => true,
        Some(Json::Bool(b)) => *b,
        Some(_) => return Err(ApiError::new(400, "bad_request", "'stream' must be a boolean")),
    };

    Ok(GenerateRequest { adapter, prompt, max_new, stop_token, stream })
}

/// Wire name of a finish reason.
pub fn reason_name(reason: FinishReason) -> &'static str {
    match reason {
        FinishReason::StopToken => "stop_token",
        FinishReason::MaxNew => "max_new",
    }
}

/// The stream's opening meta line.
pub fn meta_line(id: u64, adapter: Option<&str>) -> Json {
    let mut o = Json::obj();
    o.set("seq", jnum(id as f64));
    o.set("adapter", adapter.map(jstr).unwrap_or(Json::Null));
    o
}

/// One streamed token line.
pub fn token_line(token: usize, first: bool) -> Json {
    let mut o = Json::obj();
    o.set("first", Json::Bool(first));
    o.set("token", jnum(token as f64));
    o
}

/// The terminal done object (also the whole body when not streaming).
pub fn done_line(f: &FinishedSeq) -> Json {
    let mut o = Json::obj();
    o.set("done", Json::Bool(true));
    o.set("seq", jnum(f.id.raw() as f64));
    o.set("adapter", f.adapter.as_deref().map(jstr).unwrap_or(Json::Null));
    o.set("reason", jstr(reason_name(f.reason)));
    o.set("prompt_len", jnum(f.prompt_len as f64));
    o.set("tokens", jarr(f.generated().iter().map(|&t| jnum(t as f64))));
    o
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> ApiContext {
        ApiContext {
            vocab: 16,
            max_seq: 24,
            adapters: ["t0".to_string(), "t1".to_string()].into_iter().collect(),
        }
    }

    fn parse(body: &str) -> Result<GenerateRequest, ApiError> {
        parse_generate(body.as_bytes(), &ctx())
    }

    #[test]
    fn parses_a_full_request() {
        let r = parse(r#"{"adapter":"t0","prompt":[1,2,3],"max_new":4,"stop":7,"stream":false}"#)
            .unwrap();
        assert_eq!(r.adapter.as_deref(), Some("t0"));
        assert_eq!(r.prompt, vec![1, 2, 3]);
        assert_eq!(r.max_new, 4);
        assert_eq!(r.stop_token, Some(7));
        assert!(!r.stream);
    }

    #[test]
    fn defaults_base_adapter_streaming_and_max_new() {
        let r = parse(r#"{"prompt":[0]}"#).unwrap();
        assert_eq!(r.adapter, None);
        assert_eq!(r.max_new, DEFAULT_MAX_NEW);
        assert_eq!(r.stop_token, None);
        assert!(r.stream);
        let r2 = parse(r#"{"adapter":null,"prompt":[0]}"#).unwrap();
        assert_eq!(r2.adapter, None);
    }

    #[test]
    fn typed_rejections() {
        // (body, want_status, want_code)
        for (body, status, code) in [
            ("{", 400, "bad_json"),
            ("[1,2]", 400, "bad_json"),
            (r#"{"adapter":"ghost","prompt":[1]}"#, 404, "unknown_adapter"),
            (r#"{"adapter":7,"prompt":[1]}"#, 400, "bad_request"),
            (r#"{"prompt":[]}"#, 422, "empty_prompt"),
            (r#"{"prompt":"hi"}"#, 400, "bad_request"),
            (r#"{"prompt":[1.5]}"#, 400, "bad_request"),
            (r#"{"prompt":[-1]}"#, 400, "bad_request"),
            (r#"{"prompt":[99]}"#, 422, "token_out_of_range"),
            (r#"{"prompt":[1],"max_new":99}"#, 422, "seq_too_long"),
            (r#"{"prompt":[1],"max_new":-2}"#, 400, "bad_request"),
            (r#"{"prompt":[1],"stop":99}"#, 400, "bad_request"),
            (r#"{"prompt":[1],"stream":"yes"}"#, 400, "bad_request"),
        ] {
            let e = parse(body).unwrap_err();
            assert_eq!((e.status, e.code), (status, code), "body={body}");
        }
    }

    #[test]
    fn seq_budget_counts_prompt_plus_max_new() {
        // 20 prompt + 4 max_new == max_seq = 24: admissible.
        let tokens: Vec<String> = (0..20).map(|i| (i % 16).to_string()).collect();
        let body = format!("{{\"prompt\":[{}],\"max_new\":4}}", tokens.join(","));
        assert!(parse(&body).is_ok());
        let body = format!("{{\"prompt\":[{}],\"max_new\":5}}", tokens.join(","));
        assert_eq!(parse(&body).unwrap_err().code, "seq_too_long");
    }

    #[test]
    fn classify_maps_serve_errors_and_preserves_types() {
        let se = ServeError::UnknownAdapter { name: "g".into(), have: vec![] };
        let api = classify(&anyhow::Error::new(se));
        assert_eq!((api.status, api.code), (404, "unknown_adapter"));

        let se = ServeError::CacheBudgetExhausted { needed_bytes: 9, budget_bytes: 1 };
        let api = classify(&anyhow::Error::new(se));
        assert_eq!(api.status, 503);
        assert_eq!(api.retry_after_s, Some(1.0));

        let plain = anyhow::anyhow!("seq SeqId(0): empty prompt (a generation needs >= 1 token)");
        assert_eq!(classify(&plain).code, "empty_prompt");
        assert_eq!(classify(&anyhow::anyhow!("weird")).status, 400);
    }

    #[test]
    fn classify_maps_adapter_errors_to_structured_4xx() {
        // Registry lifecycle errors used to be anyhow strings → opaque
        // 500s at the wire; now they keep their typed status/code.
        for (err, status, code) in [
            (
                AdapterError::Unknown { name: "g".into(), have: vec!["t0".into()] },
                404,
                "unknown_adapter",
            ),
            (AdapterError::AlreadyAttached { name: "t0".into() }, 409, "adapter_already_attached"),
            (AdapterError::Merged { name: "t0".into() }, 409, "adapter_merged"),
            (AdapterError::EmptyName, 422, "empty_adapter_name"),
            (AdapterError::NoSpec { path: "x.ckpt".into() }, 422, "checkpoint_missing_spec"),
        ] {
            let api = classify(&anyhow::Error::new(err.clone()));
            assert_eq!((api.status, api.code), (status, code), "{err}");
        }
        // …including through an anyhow context chain, the way engine
        // callers actually surface them.
        let chained = anyhow::Error::new(AdapterError::Unknown {
            name: "g".into(),
            have: vec![],
        })
        .context("promoting for seq 7");
        assert_eq!(classify(&chained).status, 404);
    }

    #[test]
    fn error_body_shape_and_retry_after() {
        let e = ApiError::new(429, "rate_limited", "slow down").retry_after(2.5);
        let j = e.to_json();
        let inner = j.get("error").unwrap();
        assert_eq!(inner.get("code").and_then(|v| v.as_str()), Some("rate_limited"));
        assert_eq!(inner.get("retry_after_s").and_then(|v| v.as_f64()), Some(2.5));
    }

    #[test]
    fn retry_after_header_matches_the_body_value() {
        // Regression: the accept-queue 503 used to set 0.5 in the body
        // but hardcode `Retry-After: 1` — now both derive from one value.
        let e = ApiError::new(503, "overloaded", "full").retry_after(0.5);
        assert_eq!(e.retry_after_s, Some(0.5));
        let hdr = e.retry_after_header();
        assert_eq!(hdr, vec![("retry-after".to_string(), "1".to_string())]);
        let e = ApiError::new(429, "rate_limited", "wait").retry_after(2.0);
        assert_eq!(e.retry_after_header()[0].1, "2");
        // No hint → no header.
        assert!(ApiError::new(400, "bad_request", "x").retry_after_header().is_empty());
    }

    #[test]
    fn infinite_retry_after_is_capped_finite() {
        // Regression: a rate_per_s=0.0 tenant yields an infinite refill
        // ETA; `INFINITY.ceil() as u64` saturated the header to
        // 18446744073709551615 and the body JSON was unrepresentable.
        let e = ApiError::new(429, "rate_limited", "never").retry_after(f64::INFINITY);
        assert_eq!(e.retry_after_s, Some(MAX_RETRY_AFTER_S));
        assert_eq!(e.retry_after_header()[0].1, format!("{}", MAX_RETRY_AFTER_S as u64));
        let e = ApiError::new(429, "rate_limited", "nan").retry_after(f64::NAN);
        assert_eq!(e.retry_after_s, Some(MAX_RETRY_AFTER_S));
        let e = ApiError::new(429, "rate_limited", "huge").retry_after(1e18);
        assert_eq!(e.retry_after_s, Some(MAX_RETRY_AFTER_S));
        let e = ApiError::new(429, "rate_limited", "neg").retry_after(-3.0);
        assert_eq!(e.retry_after_s, Some(0.0));
        assert_eq!(e.retry_after_header()[0].1, "1");
    }

    #[test]
    fn stream_lines_have_the_documented_shape() {
        let m = meta_line(3, Some("t0")).to_string();
        assert!(m.contains("\"seq\":3") && m.contains("\"adapter\":\"t0\""), "{m}");
        let t = token_line(5, true).to_string();
        assert!(t.contains("\"first\":true") && t.contains("\"token\":5"), "{t}");
        let f = FinishedSeq {
            id: seq_id_for_test(7),
            adapter: None,
            prompt_len: 2,
            tokens: vec![1, 2, 9, 4],
            reason: FinishReason::MaxNew,
        };
        let d = done_line(&f).to_string();
        assert!(d.contains("\"done\":true") && d.contains("\"tokens\":[9,4]"), "{d}");
        assert!(d.contains("\"reason\":\"max_new\"") && d.contains("\"seq\":7"), "{d}");
    }

    /// `SeqId` has no public constructor; route through a scheduler.
    fn seq_id_for_test(n: u64) -> crate::serve::SeqId {
        let mut s = crate::serve::DecodeScheduler::new();
        let mut id = s.submit(crate::serve::SeqRequest::base(vec![0], 1));
        for _ in 0..n {
            id = s.submit(crate::serve::SeqRequest::base(vec![0], 1));
        }
        id
    }
}
