//! Software bfloat16 rounding — used by the Table 5 precision-comparison
//! experiment (BF16 vs FP32 full fine-tuning). bf16 keeps the f32
//! exponent and truncates the mantissa to 7 bits; we implement
//! round-to-nearest-even on the upper 16 bits.

use crate::linalg::Mat;

/// Round an f32 to the nearest bfloat16-representable value.
#[inline]
pub fn bf16_round(x: f32) -> f32 {
    let bits = x.to_bits();
    // round-to-nearest-even on bit 16
    let rounding_bias = 0x7FFF + ((bits >> 16) & 1);
    let rounded = bits.wrapping_add(rounding_bias) & 0xFFFF_0000;
    f32::from_bits(rounded)
}

/// Round all entries of a matrix to bf16 precision (simulating bf16
/// storage while computing in f32, which is what XLA CPU does too).
pub fn bf16_round_mat(m: &Mat) -> Mat {
    Mat::from_vec(m.rows, m.cols, m.data.iter().map(|&x| bf16_round(x)).collect())
}

/// In-place variant for the training loop's simulated-bf16 mode.
pub fn bf16_round_inplace(data: &mut [f32]) {
    for x in data.iter_mut() {
        *x = bf16_round(*x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values_pass_through() {
        for v in [0.0f32, 1.0, -2.0, 0.5, 256.0] {
            assert_eq!(bf16_round(v), v);
        }
    }

    #[test]
    fn rounding_error_bounded() {
        // bf16 has 8 mantissa bits (incl. implicit) => rel err <= 2^-8.
        for &v in &[1.1f32, 3.14159, -0.001234, 12345.678] {
            let r = bf16_round(v);
            assert!(((r - v) / v).abs() <= 1.0 / 256.0, "v={v} r={r}");
        }
    }

    #[test]
    fn idempotent() {
        for &v in &[1.1f32, -7.77, 0.030303] {
            let once = bf16_round(v);
            assert_eq!(bf16_round(once), once);
        }
    }

    #[test]
    fn round_to_nearest_even() {
        // 1.0 + 2^-8 is exactly halfway between 1.0 and the next bf16;
        // nearest-even rounds down to 1.0.
        let halfway = f32::from_bits(0x3F80_8000);
        assert_eq!(bf16_round(halfway), 1.0);
    }
}
