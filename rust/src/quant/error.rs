//! Quantization-error measurement (paper §4, §5.3, Appendix E/F).
//!
//! The paper quantifies error as the nuclear norm of the difference
//! between the original weight and its quantized reconstruction
//! (Eq. 6–8), and reports the *reduction ratio* relative to plain NF4
//! quantization of the base matrix (QLoRA's error):
//!     ratio = (1 − ‖W − (nf4(W') + AB)‖_* / ‖W − nf4(W)‖_*) × 100%.

use crate::linalg::{nuclear_norm, Mat};
use crate::quant::nf4::nf4_roundtrip;

/// ‖W − approx‖_* — the paper's error metric.
pub fn nuclear_error(w: &Mat, approx: &Mat) -> f64 {
    nuclear_norm(&w.sub(approx))
}

/// ‖W − approx‖_F — cheaper Frobenius variant used in Algorithm 1's
/// objective (Eq. 11/12) and in fast sweeps.
pub fn fro_error(w: &Mat, approx: &Mat) -> f64 {
    w.sub(approx).fro()
}

/// QLoRA baseline error: ‖W − nf4(W)‖_* (adapters start at AB = 0).
pub fn qlora_error(w: &Mat) -> f64 {
    nuclear_error(w, &nf4_roundtrip(w))
}

/// Error of a strategy that stores `base` quantized and `a·b` in full
/// precision: ‖W − (nf4(base) + ab)‖_*.
pub fn strategy_error(w: &Mat, base: &Mat, ab: &Mat) -> f64 {
    let approx = nf4_roundtrip(base).add(ab);
    nuclear_error(w, &approx)
}

/// The paper's reduction ratio in percent (Table 3/6, Fig 7a/13).
pub fn reduction_ratio(w: &Mat, base: &Mat, ab: &Mat) -> f64 {
    let baseline = qlora_error(w);
    if baseline == 0.0 {
        return 0.0;
    }
    (1.0 - strategy_error(w, base, ab) / baseline) * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn qlora_ratio_is_zero() {
        // QLoRA: base = W, AB = 0 ⇒ ratio = 0 by construction (Eq. 6).
        let mut rng = Rng::new(70);
        let w = Mat::randn(48, 48, 0.0, 0.05, &mut rng);
        let zero = Mat::zeros(48, 48);
        let r = reduction_ratio(&w, &w, &zero);
        assert!(r.abs() < 1e-9, "r={r}");
    }

    #[test]
    fn perfect_adapter_gives_100pct() {
        // base = 0, AB = W ⇒ error 0 ⇒ ratio 100 (nf4(0) == 0 exactly).
        let mut rng = Rng::new(71);
        let w = Mat::randn(32, 32, 0.0, 0.05, &mut rng);
        let zero = Mat::zeros(32, 32);
        let r = reduction_ratio(&w, &zero, &w);
        assert!((r - 100.0).abs() < 1e-6, "r={r}");
    }

    #[test]
    fn nuclear_ge_fro() {
        let mut rng = Rng::new(72);
        let w = Mat::randn(20, 20, 0.0, 1.0, &mut rng);
        let approx = Mat::zeros(20, 20);
        assert!(nuclear_error(&w, &approx) >= fro_error(&w, &approx) - 1e-4);
    }
}
