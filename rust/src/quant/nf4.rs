//! 4-bit NormalFloat (NF4) quantization — Dettmers et al. (QLoRA), the
//! exact scheme the paper quantizes the base/residual matrices with.
//!
//! NF4 is an information-theoretically-motivated 16-level codebook: the
//! levels are the quantiles of a standard normal, normalized to [-1, 1],
//! with an exact zero. Quantization is blockwise: each block of
//! `BLOCK` consecutive values is scaled by its absmax, then every value
//! maps to the nearest codebook entry; storage is 4 bits/value plus one
//! f32 scale per block (further compressed by double quantization, see
//! `double.rs`).

use crate::linalg::Mat;
use std::sync::Arc;

/// Values per quantization block (QLoRA uses 64).
pub const BLOCK: usize = 64;

/// The 16 NF4 codebook levels (bitsandbytes' exact constants).
pub const NF4_LEVELS: [f32; 16] = [
    -1.0,
    -0.6961928009986877,
    -0.5250730514526367,
    -0.39491748809814453,
    -0.28444138169288635,
    -0.18477343022823334,
    -0.09105003625154495,
    0.0,
    0.07958029955625534,
    0.16093020141124725,
    0.24611230194568634,
    0.33791524171829224,
    0.44070982933044434,
    0.5626170039176941,
    0.7229568362236023,
    1.0,
];

/// A blockwise NF4-quantized tensor: packed 4-bit codes + per-block scales.
#[derive(Clone, Debug)]
pub struct Nf4Tensor {
    pub rows: usize,
    pub cols: usize,
    /// Two codes per byte, low nibble first; length = ceil(rows*cols / 2).
    pub codes: Vec<u8>,
    /// One absmax scale per BLOCK values; length = ceil(rows*cols / BLOCK).
    pub scales: Vec<f32>,
}

/// One quantization block of an [`Nf4Tensor`]: values
/// `[start, start + len)` of the flattened row-major buffer, all sharing
/// `scale`. `BLOCK` is even, so every block starts byte-aligned in the
/// packed code stream and `codes` holds exactly `ceil(len / 2)` bytes.
#[derive(Clone, Copy, Debug)]
pub struct Nf4Block<'a> {
    /// Block index (`start / BLOCK`).
    pub index: usize,
    /// First flattened value index covered by this block.
    pub start: usize,
    /// Values in this block (`BLOCK`, except a shorter final block).
    pub len: usize,
    /// The block's absmax scale.
    pub scale: f32,
    codes: &'a [u8],
}

impl Nf4Block<'_> {
    /// Decode the `i`-th value of this block (`i < len`).
    #[inline]
    pub fn value(&self, i: usize) -> f32 {
        debug_assert!(i < self.len);
        let byte = self.codes[i / 2];
        let code = if i % 2 == 0 { byte & 0x0F } else { byte >> 4 };
        NF4_LEVELS[code as usize] * self.scale
    }

    /// Decode the whole block into `out[..len]`.
    pub fn dequantize_into(&self, out: &mut [f32]) {
        assert!(out.len() >= self.len, "output buffer shorter than block");
        // Pairwise nibble decode; the final odd value (short tail block
        // only) falls out of the pair loop.
        let pairs = self.len / 2;
        for p in 0..pairs {
            let byte = self.codes[p];
            out[2 * p] = NF4_LEVELS[(byte & 0x0F) as usize] * self.scale;
            out[2 * p + 1] = NF4_LEVELS[(byte >> 4) as usize] * self.scale;
        }
        if self.len % 2 == 1 {
            out[self.len - 1] = NF4_LEVELS[(self.codes[pairs] & 0x0F) as usize] * self.scale;
        }
    }
}

impl Nf4Tensor {
    /// Total flattened value count (`rows * cols`).
    pub fn len(&self) -> usize {
        self.rows * self.cols
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Number of quantization blocks (`ceil(len / BLOCK)`).
    pub fn n_blocks(&self) -> usize {
        self.len().div_ceil(BLOCK)
    }

    /// The `b`-th quantization block.
    pub fn block(&self, b: usize) -> Nf4Block<'_> {
        let start = b * BLOCK;
        let len = (start + BLOCK).min(self.len()) - start;
        Nf4Block {
            index: b,
            start,
            len,
            scale: self.scales[b],
            codes: &self.codes[start / 2..(start + len).div_ceil(2)],
        }
    }

    /// Iterate the quantization blocks in flattened order — the streaming
    /// API the fused dequant-GEMM serving path is built on: consumers
    /// decode one cache-sized panel of blocks at a time and never
    /// materialize the dense matrix.
    pub fn blocks(&self) -> impl Iterator<Item = Nf4Block<'_>> {
        (0..self.n_blocks()).map(|b| self.block(b))
    }

    /// Decode the flattened value range `[lo, hi)` into `out` (length
    /// `hi - lo`). The range may start/end mid-block — panel widths that
    /// don't divide `BLOCK` are fine (and exercised by the determinism
    /// suite). Bit-identical to slicing a full [`dequantize`].
    pub fn dequantize_range(&self, lo: usize, hi: usize, out: &mut [f32]) {
        assert!(lo <= hi && hi <= self.len(), "range [{lo}, {hi}) out of bounds");
        assert_eq!(out.len(), hi - lo, "output buffer/range length mismatch");
        if lo == hi {
            return;
        }
        let mut pos = lo;
        for b in lo / BLOCK..=(hi - 1) / BLOCK {
            let blk = self.block(b);
            let stop = hi.min(blk.start + blk.len);
            if pos == blk.start && stop == blk.start + blk.len {
                // Whole block: fast pairwise decode.
                blk.dequantize_into(&mut out[pos - lo..stop - lo]);
            } else {
                for i in pos..stop {
                    out[i - lo] = blk.value(i - blk.start);
                }
            }
            pos = stop;
        }
    }

    /// Bytes resident for this tensor (packed codes + f32 scales); see
    /// the free function [`storage_bytes`].
    pub fn storage_bytes(&self) -> usize {
        self.codes.len() + self.scales.len() * 4
    }
}

/// Shared NF4 snapshot of a stacked per-layer weight (`[L, m, n]` sliced
/// into L matrices). Every layer is quantized blockwise on its own —
/// self-contained scales, so one layer can be dequantized or streamed
/// through the dequant-GEMM without touching its neighbors — and the
/// per-layer tensors sit behind `Arc`s, so every consumer of the stack
/// (e.g. the L per-layer units of the full-model serving pipeline)
/// serves from the SAME resident codes instead of quantizing or copying
/// its own snapshot.
#[derive(Clone, Debug)]
pub struct Nf4Stack {
    layers: Arc<[Arc<Nf4Tensor>]>,
}

impl Nf4Stack {
    /// Quantize each layer matrix once. The layers usually share a shape
    /// (a stacked weight) but are not required to.
    pub fn quantize_layers(mats: &[Mat]) -> Nf4Stack {
        Nf4Stack { layers: mats.iter().map(|m| Arc::new(quantize(m))).collect() }
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Shared handle to layer `l`'s NF4 tensor (an `Arc` clone — no code
    /// or scale bytes are copied).
    pub fn layer(&self, l: usize) -> Arc<Nf4Tensor> {
        self.layers[l].clone()
    }

    /// Total resident bytes across all layers (packed codes + scales).
    pub fn storage_bytes(&self) -> usize {
        self.layers.iter().map(|t| t.storage_bytes()).sum()
    }
}

/// Decision boundaries between adjacent codebook levels (midpoints):
/// nearest level of x = number of boundaries strictly below x.
/// (§Perf: replaced a branchy binary search — the 15 comparisons are
/// branchless and LLVM vectorizes the whole block loop; quantize
/// throughput went 0.13 → ~1 GB/s on this machine.)
const NF4_BOUNDARIES: [f32; 15] = [
    (NF4_LEVELS[0] + NF4_LEVELS[1]) / 2.0,
    (NF4_LEVELS[1] + NF4_LEVELS[2]) / 2.0,
    (NF4_LEVELS[2] + NF4_LEVELS[3]) / 2.0,
    (NF4_LEVELS[3] + NF4_LEVELS[4]) / 2.0,
    (NF4_LEVELS[4] + NF4_LEVELS[5]) / 2.0,
    (NF4_LEVELS[5] + NF4_LEVELS[6]) / 2.0,
    (NF4_LEVELS[6] + NF4_LEVELS[7]) / 2.0,
    (NF4_LEVELS[7] + NF4_LEVELS[8]) / 2.0,
    (NF4_LEVELS[8] + NF4_LEVELS[9]) / 2.0,
    (NF4_LEVELS[9] + NF4_LEVELS[10]) / 2.0,
    (NF4_LEVELS[10] + NF4_LEVELS[11]) / 2.0,
    (NF4_LEVELS[11] + NF4_LEVELS[12]) / 2.0,
    (NF4_LEVELS[12] + NF4_LEVELS[13]) / 2.0,
    (NF4_LEVELS[13] + NF4_LEVELS[14]) / 2.0,
    (NF4_LEVELS[14] + NF4_LEVELS[15]) / 2.0,
];

/// Map a normalized value in [-1, 1] to the nearest codebook index —
/// branchless boundary count (ties at an exact midpoint round up to the
/// higher level, matching `(x - lo).abs() <= (hi - x).abs()` ⇒ lo only
/// when strictly closer or exactly tied… midpoints resolve to lo there;
/// we preserve that by counting strict `>` against the boundary).
#[inline]
pub fn nearest_code(x: f32) -> u8 {
    let mut code = 0u8;
    for b in NF4_BOUNDARIES {
        code += (x > b) as u8;
    }
    code
}

/// Quantize a matrix to NF4 (blockwise absmax over the flattened
/// row-major buffer, matching bitsandbytes' flattened layout).
///
/// §Perf: the hot loop processes one 64-value block at a time — absmax
/// reduction, branchless code computation into a stack array (no
/// read-modify-write on the output), then pairwise nibble packing.
pub fn quantize(m: &Mat) -> Nf4Tensor {
    let n = m.data.len();
    let nblocks = n.div_ceil(BLOCK);
    let mut scales = vec![0.0f32; nblocks];
    let mut codes = vec![0u8; n.div_ceil(2)];
    let mut block_codes = [0u8; BLOCK];
    for b in 0..nblocks {
        let lo = b * BLOCK;
        let hi = (lo + BLOCK).min(n);
        let chunk = &m.data[lo..hi];
        let absmax = chunk.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        scales[b] = absmax;
        let inv = if absmax > 0.0 { 1.0 / absmax } else { 0.0 };
        for (c, &x) in block_codes.iter_mut().zip(chunk) {
            *c = nearest_code(x * inv);
        }
        let len = hi - lo;
        // BLOCK is even, so only the final short block can have a tail.
        let pairs = len / 2;
        let dst = &mut codes[lo / 2..lo / 2 + len.div_ceil(2)];
        for p in 0..pairs {
            dst[p] = block_codes[2 * p] | (block_codes[2 * p + 1] << 4);
        }
        if len % 2 == 1 {
            dst[pairs] = block_codes[len - 1];
        }
    }
    Nf4Tensor { rows: m.rows, cols: m.cols, codes, scales }
}

/// Dequantize back to f32 (block-by-block through the streaming API, so
/// this is by construction bit-identical to any panel decomposition via
/// [`Nf4Tensor::dequantize_range`]).
pub fn dequantize(t: &Nf4Tensor) -> Mat {
    let mut data = vec![0.0f32; t.len()];
    for blk in t.blocks() {
        blk.dequantize_into(&mut data[blk.start..blk.start + blk.len]);
    }
    Mat::from_vec(t.rows, t.cols, data)
}

/// One-call round trip: deq(quant(m)) — the "nf4(·)" of the paper's Eq. 6/8.
pub fn nf4_roundtrip(m: &Mat) -> Mat {
    dequantize(&quantize(m))
}

/// Bytes of storage used by the quantized representation (codes + f32
/// scales, before double quantization; see `double::storage_bytes` for
/// the second-level scale metadata accounting).
pub fn storage_bytes(t: &Nf4Tensor) -> usize {
    t.storage_bytes()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn codebook_is_sorted_and_has_zero() {
        for w in NF4_LEVELS.windows(2) {
            assert!(w[0] < w[1]);
        }
        assert_eq!(NF4_LEVELS[7], 0.0);
        assert_eq!(NF4_LEVELS[0], -1.0);
        assert_eq!(NF4_LEVELS[15], 1.0);
    }

    #[test]
    fn nearest_code_exact_levels() {
        for (i, &v) in NF4_LEVELS.iter().enumerate() {
            assert_eq!(nearest_code(v) as usize, i);
        }
        assert_eq!(nearest_code(-2.0), 0);
        assert_eq!(nearest_code(2.0), 15);
    }

    #[test]
    fn roundtrip_error_bounded() {
        // Max normalized error is half the largest codebook gap times absmax.
        let mut rng = Rng::new(50);
        let m = Mat::randn(32, 48, 0.0, 0.05, &mut rng);
        let rt = nf4_roundtrip(&m);
        let max_gap = NF4_LEVELS.windows(2).map(|w| w[1] - w[0]).fold(0.0f32, f32::max);
        for (blk, chunk) in m.data.chunks(BLOCK).enumerate() {
            let absmax = chunk.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
            for (i, &x) in chunk.iter().enumerate() {
                let idx = blk * BLOCK + i;
                let err = (x - rt.data[idx]).abs();
                assert!(err <= 0.5 * max_gap * absmax + 1e-7, "err={err} absmax={absmax}");
            }
        }
    }

    #[test]
    fn zeros_and_extremes_are_exact() {
        let m = Mat::from_vec(1, 4, vec![0.0, 1.0, -1.0, 0.5]);
        let rt = nf4_roundtrip(&m);
        assert_eq!(rt.data[0], 0.0);
        assert_eq!(rt.data[1], 1.0); // absmax element is exact
        assert_eq!(rt.data[2], -1.0);
    }

    #[test]
    fn quantization_is_idempotent() {
        let mut rng = Rng::new(51);
        let m = Mat::randn(16, 16, 0.0, 1.0, &mut rng);
        let once = nf4_roundtrip(&m);
        let twice = nf4_roundtrip(&once);
        // Quantized values are fixed points of the quantizer.
        for (a, b) in once.data.iter().zip(&twice.data) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn storage_is_about_half_byte_per_value() {
        let mut rng = Rng::new(52);
        let m = Mat::randn(64, 64, 0.0, 1.0, &mut rng);
        let t = quantize(&m);
        let bytes = storage_bytes(&t);
        let raw = 64 * 64 * 4;
        // 4 bits/val + scale overhead => ~0.5625 bytes/val for BLOCK=64.
        assert!(bytes * 6 < raw, "bytes={bytes} raw={raw}");
    }

    #[test]
    fn narrower_distribution_quantizes_better() {
        // The core of the paper's QPiSSA argument (§4): removing the
        // principal components narrows the distribution and reduces error.
        let mut rng = Rng::new(53);
        let wide = Mat::randn(64, 64, 0.0, 1.0, &mut rng);
        let narrow = Mat::randn(64, 64, 0.0, 0.3, &mut rng);
        let ew = wide.sub(&nf4_roundtrip(&wide)).fro();
        let en = narrow.sub(&nf4_roundtrip(&narrow)).fro();
        assert!(en < ew, "narrow err {en} should be < wide err {ew}");
    }

    #[test]
    fn odd_length_blocks() {
        let m = Mat::from_vec(1, 67, (0..67).map(|i| (i as f32 - 33.0) / 33.0).collect());
        let rt = nf4_roundtrip(&m);
        assert_eq!(rt.data.len(), 67);
        assert!(rt.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn block_iterator_tiles_the_tensor() {
        let mut rng = Rng::new(54);
        // 3×70 = 210 values: 3 full blocks + an 18-value tail block.
        let m = Mat::randn(3, 70, 0.0, 1.0, &mut rng);
        let t = quantize(&m);
        assert_eq!(t.len(), 210);
        assert_eq!(t.n_blocks(), 4);
        let dense = dequantize(&t);
        let mut covered = 0;
        for blk in t.blocks() {
            assert_eq!(blk.start, covered);
            assert_eq!(blk.scale, t.scales[blk.index]);
            let mut buf = vec![0.0f32; blk.len];
            blk.dequantize_into(&mut buf);
            assert_eq!(buf, dense.data[blk.start..blk.start + blk.len]);
            for i in 0..blk.len {
                assert_eq!(blk.value(i), dense.data[blk.start + i]);
            }
            covered += blk.len;
        }
        assert_eq!(covered, t.len());
        assert_eq!(t.blocks().last().unwrap().len, 18);
    }

    #[test]
    fn stack_layers_share_codes_and_match_per_layer_quantize() {
        let mut rng = Rng::new(56);
        let mats: Vec<Mat> = (0..3).map(|_| Mat::randn(6, 23, 0.0, 0.8, &mut rng)).collect();
        let stack = Nf4Stack::quantize_layers(&mats);
        assert_eq!(stack.n_layers(), 3);
        let mut total = 0;
        for (l, m) in mats.iter().enumerate() {
            let solo = quantize(m);
            let shared = stack.layer(l);
            // Layer-local quantization: identical to quantizing the layer
            // alone (scales never straddle layers).
            assert_eq!(shared.codes, solo.codes, "layer {l} codes");
            assert_eq!(shared.scales, solo.scales, "layer {l} scales");
            total += shared.storage_bytes();
            // Handing out another handle shares the allocation.
            assert!(Arc::ptr_eq(&shared, &stack.layer(l)));
        }
        assert_eq!(stack.storage_bytes(), total);
    }

    #[test]
    fn dequantize_range_matches_full_decode_on_unaligned_panels() {
        let mut rng = Rng::new(55);
        let m = Mat::randn(5, 37, 0.0, 0.7, &mut rng); // 185 values, ragged blocks
        let t = quantize(&m);
        let dense = dequantize(&t);
        for &(lo, hi) in
            &[(0usize, 185usize), (0, 1), (63, 65), (1, 184), (64, 128), (100, 100), (130, 185)]
        {
            let mut buf = vec![0.0f32; hi - lo];
            t.dequantize_range(lo, hi, &mut buf);
            assert_eq!(buf, dense.data[lo..hi], "range [{lo}, {hi})");
        }
    }
}
