//! Double quantization (QLoRA §"Double Quantization"): the per-block f32
//! absmax scales are themselves quantized to 8 bits with one f32
//! (scale, offset) pair per group of 256 blocks, cutting the scale
//! overhead from 32 to ~8.5 bits per block (0.5 → 0.127 bits/param).

use super::nf4::Nf4Tensor;

/// Scales per second-level quantization group.
pub const GROUP: usize = 256;

/// Double-quantized scale storage.
#[derive(Clone, Debug)]
pub struct DoubleQuantScales {
    /// 8-bit codes, one per original scale.
    pub codes: Vec<u8>,
    /// Per-group (offset, step) pairs: scale ≈ offset + step * code.
    pub groups: Vec<(f32, f32)>,
}

/// Quantize a vector of f32 scales to 8-bit affine codes per group.
pub fn quantize_scales(scales: &[f32]) -> DoubleQuantScales {
    let mut codes = vec![0u8; scales.len()];
    let mut groups = Vec::with_capacity(scales.len().div_ceil(GROUP));
    for (g, chunk) in scales.chunks(GROUP).enumerate() {
        let lo = chunk.iter().cloned().fold(f32::INFINITY, f32::min);
        let hi = chunk.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let step = if hi > lo { (hi - lo) / 255.0 } else { 0.0 };
        groups.push((lo, step));
        for (i, &s) in chunk.iter().enumerate() {
            let code = if step > 0.0 { ((s - lo) / step).round().clamp(0.0, 255.0) as u8 } else { 0 };
            codes[g * GROUP + i] = code;
        }
    }
    DoubleQuantScales { codes, groups }
}

/// Dequantize scale codes back to f32.
pub fn dequantize_scales(dq: &DoubleQuantScales) -> Vec<f32> {
    dq.codes
        .iter()
        .enumerate()
        .map(|(i, &c)| {
            let (lo, step) = dq.groups[i / GROUP];
            lo + step * c as f32
        })
        .collect()
}

/// Bytes resident for double-quantized scale storage: one u8 code per
/// original scale plus one `(f32, f32)` affine pair per group. The
/// counterpart of `nf4::storage_bytes` for the second quantization level.
pub fn storage_bytes(dq: &DoubleQuantScales) -> usize {
    dq.codes.len() + dq.groups.len() * 8
}

/// Apply double quantization to an NF4 tensor in place (replaces its f32
/// scales with their double-quantized round trip) and return the storage
/// saving in bytes.
pub fn double_quantize(t: &mut Nf4Tensor) -> usize {
    let before = t.scales.len() * 4;
    let dq = quantize_scales(&t.scales);
    t.scales = dequantize_scales(&dq);
    let after = storage_bytes(&dq);
    before.saturating_sub(after)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::quant::nf4::{dequantize, quantize};
    use crate::util::rng::Rng;

    #[test]
    fn scale_roundtrip_error_small() {
        let mut rng = Rng::new(60);
        let scales: Vec<f32> = (0..1000).map(|_| rng.uniform_in(0.01, 0.2)).collect();
        let dq = quantize_scales(&scales);
        let back = dequantize_scales(&dq);
        for (a, b) in scales.iter().zip(&back) {
            // 8-bit affine over the group range: error ≤ step/2 ≤ range/510.
            assert!((a - b).abs() <= (0.2 - 0.01) / 510.0 + 1e-6);
        }
    }

    #[test]
    fn constant_scales_exact() {
        let scales = vec![0.5f32; 300];
        let back = dequantize_scales(&quantize_scales(&scales));
        for b in back {
            assert_eq!(b, 0.5);
        }
    }

    #[test]
    fn double_quant_saves_memory_and_keeps_error_small() {
        let mut rng = Rng::new(61);
        let m = Mat::randn(128, 128, 0.0, 0.05, &mut rng);
        let mut t = quantize(&m);
        let base_err = m.sub(&dequantize(&t)).fro();
        let saved = double_quantize(&mut t);
        assert!(saved > 0, "saved={saved}");
        let dq_err = m.sub(&dequantize(&t)).fro();
        // Double quantization should cost < 5% extra error on Gaussian data.
        assert!(dq_err < base_err * 1.05, "base={base_err} dq={dq_err}");
    }
}
