//! Quantization substrate: 4-bit NormalFloat (NF4) with blockwise absmax
//! scaling, double quantization of the scales, software bf16 rounding, and
//! the paper's nuclear-norm quantization-error analysis.

pub mod bf16;
pub mod double;
pub mod error;
pub mod nf4;

pub use error::{fro_error, qlora_error, reduction_ratio, strategy_error};
pub use nf4::{dequantize, nf4_roundtrip, quantize, storage_bytes, Nf4Block, Nf4Stack, Nf4Tensor};
