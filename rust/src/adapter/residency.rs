//! Adapter residency tiering — the hot/warm/cold cache hierarchy that
//! lets one engine serve far more registered tenants than fit in RAM.
//!
//! PiSSA's Appendix-C export makes every tenant a tiny `(m+n)·r` delta
//! over ONE shared frozen base, so the per-tenant state is cheap — but
//! the engine kept every attached adapter resident in f32 forever and
//! the server snapshotted its adapter set immutably at construction.
//! [`TierManager`] closes that gap with a three-tier cache:
//!
//! * **hot** — f32 factors in the engine plus the prepared `serve_delta`
//!   in every `LinearServer`; served directly.
//! * **warm** — an in-RAM blockwise-NF4 copy of the adapter's tensors
//!   (~0.14× the f32 bytes), promoted to hot by deterministic
//!   dequantization. Lossy once, then stable: NF4 quantization is a
//!   fixed point, so every warm round trip after the first is
//!   bit-identical.
//! * **cold** — an on-disk `PISSACKP` checkpoint, attached lazily on
//!   first request (`attach_cold`). Cold reload is LOSSLESS: demotion
//!   spills the exact f32 tensors before anything is dropped, so a
//!   full-precision adapter's served trajectory is bitwise invariant to
//!   its eviction history.
//!
//! Eviction is LRU over a working-set clock advanced once per
//! [`TierManager::ensure_resident`] call (one call per scheduler step
//! boundary — promotion work NEVER runs inside the batched decode hot
//! loop), cross-checked against the per-adapter hit counters
//! `ServeStats` already collects via [`TierManager::sync_hits`]. The
//! resident-byte budget (`ServeConfig::adapter_budget_bytes`) counts hot
//! f32 bytes (engine tensors + prepared server deltas) plus warm NF4
//! bytes; cold costs only disk.

use super::engine::{AdapterEngine, NamedAdapter};
use super::spec::AdapterSpec;
use crate::model::{ParamStore, Tensor};
use crate::quant::{dequantize, Nf4Stack};
use crate::serve::ModelServer;
use anyhow::Result;
use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};
use std::time::Instant;

/// Pinned bound on the warm copy's NF4 round-trip error, per tensor:
/// `‖T − deq(nf4(T))‖_F / ‖T‖_F` must not exceed this when a warm copy
/// is made (the same blockwise round trip the fused-quant serving path
/// bounds; asserted at demote time, when the original is still in hand).
pub const WARM_NF4_REL_TOL: f64 = 0.25;

/// Window of attach-on-miss latency samples kept for the p95 estimate.
const ATTACH_WINDOW: usize = 4096;

/// Residency tier of one registered adapter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    Hot,
    Warm,
    Cold,
}

impl Tier {
    pub fn name(&self) -> &'static str {
        match self {
            Tier::Hot => "hot",
            Tier::Warm => "warm",
            Tier::Cold => "cold",
        }
    }
}

/// Where an adapter goes when it is evicted from the hot tier.
///
/// `Exact` (the default) drops straight to cold: the only copies kept
/// are lossless, so every reload is bit-identical to the pre-eviction
/// state. `Compressed` keeps the NF4 warm copy resident as a middle
/// tier: promotion skips the disk read and the attach-time revalidation,
/// at the (bounded, then stable) NF4 round-trip error.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DemotePolicy {
    #[default]
    Exact,
    Compressed,
}

/// Blockwise-NF4 copy of one adapter's tensors — the warm tier's
/// resident representation (~0.14× the f32 bytes: 4-bit codes plus one
/// f32 scale per 64-value block).
#[derive(Debug, Clone)]
pub struct WarmAdapter {
    name: String,
    spec: AdapterSpec,
    /// One NF4 stack per stored tensor, keyed `frozen.*` / `factors.*` /
    /// `init.*` like the checkpoint layout.
    stacks: BTreeMap<String, Nf4Stack>,
}

impl WarmAdapter {
    /// Quantize an attached adapter's tensors into a warm copy,
    /// asserting the pinned round-trip bound per tensor while the
    /// original is still available.
    pub(crate) fn from_named(name: &str, ad: &NamedAdapter) -> Result<WarmAdapter> {
        let mut stacks = BTreeMap::new();
        for (prefix, store) in
            [("frozen", &ad.frozen), ("factors", &ad.factors), ("init", &ad.init_factors)]
        {
            for (k, t) in store {
                let layers: Vec<_> = (0..t.shape[0]).map(|li| t.layer(li)).collect();
                let stack = Nf4Stack::quantize_layers(&layers);
                for (li, orig) in layers.iter().enumerate() {
                    let rt = dequantize(&stack.layer(li));
                    let rel = orig.sub(&rt).fro() / orig.fro().max(1e-30);
                    anyhow::ensure!(
                        rel <= WARM_NF4_REL_TOL,
                        "warm copy of '{name}' {prefix}.{k}[{li}]: NF4 round-trip rel \
                         err {rel:.3e} exceeds the pinned bound {WARM_NF4_REL_TOL}"
                    );
                }
                stacks.insert(format!("{prefix}.{k}"), stack);
            }
        }
        Ok(WarmAdapter { name: name.to_string(), spec: ad.spec.clone(), stacks })
    }

    /// Deterministic dequantization back into an attachable adapter.
    /// Same warm copy in, bit-identical tensors out, every time.
    pub(crate) fn to_named(&self) -> NamedAdapter {
        let mut frozen = ParamStore::new();
        let mut factors = ParamStore::new();
        let mut init_factors = ParamStore::new();
        for (key, stack) in &self.stacks {
            let mats: Vec<_> =
                (0..stack.n_layers()).map(|li| dequantize(&stack.layer(li))).collect();
            let t = Tensor::stack(&mats);
            let (prefix, k) = key.split_once('.').expect("warm keys are prefixed");
            match prefix {
                "frozen" => frozen.insert(k.to_string(), t),
                "factors" => factors.insert(k.to_string(), t),
                "init" => init_factors.insert(k.to_string(), t),
                other => unreachable!("unknown warm store prefix {other}"),
            };
        }
        NamedAdapter { spec: self.spec.clone(), frozen, factors, init_factors }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Resident bytes of the warm copy (packed codes + f32 scales).
    pub fn bytes(&self) -> usize {
        self.stacks.values().map(|s| s.storage_bytes()).sum()
    }
}

/// Promotion/demotion traffic counters, surfaced through `/metrics`.
#[derive(Debug, Clone, Default)]
pub struct TierCounters {
    /// Warm→hot and cold→hot promotions.
    pub promotions: usize,
    /// Hot→warm/cold demotions (evictions).
    pub demotions: usize,
    /// Promotions that went through the on-disk attach path.
    pub cold_attaches: usize,
    /// `ensure_resident` calls that could not fit the budget because the
    /// current working set alone exceeds it (nothing evictable).
    pub over_budget: usize,
}

#[derive(Debug)]
struct Entry {
    tier: Tier,
    policy: DemotePolicy,
    /// f32 bytes while hot: engine tensors + prepared server deltas.
    hot_bytes: usize,
    /// NF4 copy while warm.
    warm: Option<WarmAdapter>,
    /// Lossless checkpoint: the registered cold file, replaced by the
    /// spill written at first demotion.
    ckpt: Option<PathBuf>,
    /// Working-set clock value of the last touch (LRU key).
    last_used: u64,
    /// Last synced `ServeStats` hit count for this adapter.
    hits: usize,
}

/// LRU residency manager over one engine/server pair.
///
/// The manager owns the POLICY only: the engine owns the f32 tensors,
/// the server owns the prepared deltas, and `ensure_resident` moves
/// adapters between tiers through their public lifecycle ops at step
/// boundaries. Engine and server stay view-consistent: an adapter is
/// either in both (hot) or in neither (warm/cold).
#[derive(Debug)]
pub struct TierManager {
    budget_bytes: usize,
    spill_dir: PathBuf,
    clock: u64,
    entries: BTreeMap<String, Entry>,
    counters: TierCounters,
    /// Rolling window of promotion latencies (attach-on-miss cost).
    attach_s: Vec<f64>,
}

impl TierManager {
    /// A manager enforcing `budget_bytes` of resident adapter state,
    /// spilling demoted adapters' lossless checkpoints under `spill_dir`.
    pub fn new(budget_bytes: usize, spill_dir: impl Into<PathBuf>) -> TierManager {
        TierManager {
            budget_bytes,
            spill_dir: spill_dir.into(),
            clock: 0,
            entries: BTreeMap::new(),
            counters: TierCounters::default(),
            attach_s: Vec::new(),
        }
    }

    /// Track an adapter that is already attached in the engine AND
    /// served by `server` (the boot-time resident set).
    pub fn register_hot(
        &mut self,
        name: &str,
        engine: &AdapterEngine,
        server: &ModelServer,
    ) -> Result<()> {
        anyhow::ensure!(
            !self.entries.contains_key(name),
            "adapter '{name}' is already tier-registered"
        );
        anyhow::ensure!(server.serves_adapter(name), "server does not serve '{name}'");
        let hot_bytes = engine.adapter_bytes(name)? + server.adapter_delta_bytes(name);
        self.entries.insert(
            name.to_string(),
            Entry {
                tier: Tier::Hot,
                policy: DemotePolicy::default(),
                hot_bytes,
                warm: None,
                ckpt: None,
                last_used: self.clock,
                hits: 0,
            },
        );
        Ok(())
    }

    /// Register a cold tenant: a name bound to an on-disk `PISSACKP`,
    /// attached lazily on first request. Costs one map entry now —
    /// nothing is loaded or validated until the first miss (validation
    /// runs in full at attach time). Many tenant names may share one
    /// checkpoint file.
    pub fn register_cold(&mut self, name: &str, path: impl Into<PathBuf>) -> Result<()> {
        anyhow::ensure!(
            !self.entries.contains_key(name),
            "adapter '{name}' is already tier-registered"
        );
        self.entries.insert(
            name.to_string(),
            Entry {
                tier: Tier::Cold,
                policy: DemotePolicy::default(),
                hot_bytes: 0,
                warm: None,
                ckpt: Some(path.into()),
                last_used: self.clock,
                hits: 0,
            },
        );
        Ok(())
    }

    /// Choose where `name` goes when evicted (default [`DemotePolicy::Exact`]).
    pub fn set_policy(&mut self, name: &str, policy: DemotePolicy) -> Result<()> {
        let e = self
            .entries
            .get_mut(name)
            .ok_or_else(|| anyhow::anyhow!("adapter '{name}' is not tier-registered"))?;
        e.policy = policy;
        Ok(())
    }

    /// All tier-registered names (sorted) — the full routable tenant
    /// set, hot or not.
    pub fn names(&self) -> Vec<&str> {
        self.entries.keys().map(|s| s.as_str()).collect()
    }

    pub fn tier(&self, name: &str) -> Option<Tier> {
        self.entries.get(name).map(|e| e.tier)
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    pub fn counters(&self) -> &TierCounters {
        &self.counters
    }

    /// RAM currently held by registered adapters: hot f32 + warm NF4.
    pub fn resident_bytes(&self) -> usize {
        self.entries
            .values()
            .map(|e| match e.tier {
                Tier::Hot => e.hot_bytes,
                Tier::Warm => e.warm.as_ref().map_or(0, |w| w.bytes()),
                Tier::Cold => 0,
            })
            .sum()
    }

    /// Per-tier `(tier, adapter count, resident bytes)` table — the
    /// `ResidentBreakdown` rows surfaced through `/metrics`.
    pub fn tier_table(&self) -> Vec<(&'static str, usize, usize)> {
        let mut rows = [(Tier::Hot, 0, 0), (Tier::Warm, 0, 0), (Tier::Cold, 0, 0)];
        for e in self.entries.values() {
            let row = &mut rows[match e.tier {
                Tier::Hot => 0,
                Tier::Warm => 1,
                Tier::Cold => 2,
            }];
            row.1 += 1;
            row.2 += match e.tier {
                Tier::Hot => e.hot_bytes,
                Tier::Warm => e.warm.as_ref().map_or(0, |w| w.bytes()),
                Tier::Cold => 0,
            };
        }
        rows.iter().map(|(t, c, b)| (t.name(), *c, *b)).collect()
    }

    /// Fold the serving layer's per-adapter hit counters into the LRU
    /// clock: any adapter whose count grew since the last sync was used
    /// by the batch that just ran, so it is touched at the current clock.
    pub fn sync_hits(&mut self, hits: &BTreeMap<String, usize>) {
        for (name, &n) in hits {
            if let Some(e) = self.entries.get_mut(name) {
                if n > e.hits {
                    e.hits = n;
                    e.last_used = self.clock;
                }
            }
        }
    }

    /// Nearest-rank p95 of the promotion (attach-on-miss) latencies.
    pub fn attach_p95_s(&self) -> f64 {
        if self.attach_s.is_empty() {
            return 0.0;
        }
        let mut v = self.attach_s.clone();
        v.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));
        v[((v.len() as f64 * 0.95).ceil() as usize).clamp(1, v.len()) - 1]
    }

    /// The step-boundary hook: promote every `wanted` adapter to hot
    /// (attach-on-miss), then evict LRU non-wanted adapters until the
    /// resident bytes fit the budget. Returns per-adapter promotion
    /// failures (the batch's requests for those names will then draw the
    /// typed `UnknownAdapter` rejection from the serving layer);
    /// unregistered names are ignored entirely.
    ///
    /// Engine and server stay consistent on every path: a promotion
    /// that fails server-side rolls the engine attach back, and a
    /// demotion that fails engine-side restores the server group.
    pub fn ensure_resident(
        &mut self,
        engine: &mut AdapterEngine,
        server: &mut ModelServer,
        wanted: &[String],
    ) -> Vec<(String, anyhow::Error)> {
        self.clock += 1;
        let clock = self.clock;
        let mut failures = Vec::new();
        for name in wanted {
            let Some(e) = self.entries.get_mut(name) else { continue };
            e.last_used = clock;
            if e.tier == Tier::Hot {
                continue;
            }
            let t0 = Instant::now();
            match Self::promote_entry(name, e, engine, server, &mut self.counters) {
                Ok(()) => {
                    if self.attach_s.len() >= ATTACH_WINDOW {
                        self.attach_s.remove(0);
                    }
                    self.attach_s.push(t0.elapsed().as_secs_f64());
                }
                Err(err) => failures.push((name.clone(), err)),
            }
        }
        let wanted_set: BTreeSet<&str> = wanted.iter().map(|s| s.as_str()).collect();
        while self.resident_bytes() > self.budget_bytes {
            let Some(victim) = self.lru_victim(&wanted_set) else {
                self.counters.over_budget += 1;
                break;
            };
            if let Err(err) = self.demote(engine, server, &victim) {
                failures.push((victim, err));
                break;
            }
        }
        failures
    }

    /// Least-recently-used evictable adapter: hot entries first (the
    /// expensive tier), then warm; `wanted` names — the step's working
    /// set — are never victims. Ties break on name (BTreeMap order), so
    /// eviction is deterministic.
    fn lru_victim(&self, wanted: &BTreeSet<&str>) -> Option<String> {
        for tier in [Tier::Hot, Tier::Warm] {
            let victim = self
                .entries
                .iter()
                .filter(|(n, e)| e.tier == tier && !wanted.contains(n.as_str()))
                .min_by_key(|(_, e)| e.last_used)
                .map(|(n, _)| n.clone());
            if victim.is_some() {
                return victim;
            }
        }
        None
    }

    fn promote_entry(
        name: &str,
        e: &mut Entry,
        engine: &mut AdapterEngine,
        server: &mut ModelServer,
        counters: &mut TierCounters,
    ) -> Result<()> {
        match e.tier {
            Tier::Hot => return Ok(()),
            Tier::Warm => {
                let warm = e.warm.as_ref().expect("warm entries carry their NF4 copy");
                engine.promote(warm)?;
            }
            Tier::Cold => {
                let path = e
                    .ckpt
                    .clone()
                    .ok_or_else(|| anyhow::anyhow!("cold entry '{name}' has no checkpoint"))?;
                engine.attach_cold(name, &path)?;
                counters.cold_attaches += 1;
            }
        }
        if let Err(err) = server.add_adapter(engine, name) {
            engine.detach(name).ok(); // roll back: keep the views consistent
            return Err(err);
        }
        e.warm = None; // re-created at the next demotion (NF4 is idempotent)
        e.tier = Tier::Hot;
        e.hot_bytes = engine.adapter_bytes(name).unwrap_or(0) + server.adapter_delta_bytes(name);
        counters.promotions += 1;
        Ok(())
    }

    /// Demote one hot adapter per its policy (public so tests and the
    /// churn bench can force evictions mid-trajectory). Warm entries can
    /// also be demoted — that just drops the RAM copy (the lossless
    /// spill stays on disk).
    pub fn demote(
        &mut self,
        engine: &mut AdapterEngine,
        server: &mut ModelServer,
        name: &str,
    ) -> Result<()> {
        let spill = self.spill_dir.join(format!("{name}.ckpt"));
        let e = self
            .entries
            .get_mut(name)
            .ok_or_else(|| anyhow::anyhow!("adapter '{name}' is not tier-registered"))?;
        match e.tier {
            Tier::Cold => return Ok(()),
            Tier::Warm => {
                e.warm = None;
                e.tier = Tier::Cold;
                return Ok(());
            }
            Tier::Hot => {}
        }
        server.remove_adapter(name)?;
        let warm = match engine.demote(name, &spill) {
            Ok(w) => w,
            Err(err) => {
                server.add_adapter(engine, name).ok(); // restore the serving view
                return Err(err);
            }
        };
        e.ckpt = Some(spill);
        e.hot_bytes = 0;
        match e.policy {
            DemotePolicy::Compressed => {
                e.warm = Some(warm);
                e.tier = Tier::Warm;
            }
            DemotePolicy::Exact => e.tier = Tier::Cold,
        }
        self.counters.demotions += 1;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter::AdapterSpec;
    use crate::model::{BaseModel, LINEARS};
    use crate::runtime::ConfigInfo;
    use crate::serve::{drift_factors, ServeConfig};
    use crate::util::rng::Rng;

    fn tiny_cfg() -> ConfigInfo {
        ConfigInfo {
            name: "residency-test".into(),
            kind: "decoder".into(),
            vocab: 48,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 24,
            seq_len: 16,
            batch: 4,
            eval_batch: 2,
            n_classes: 0,
            ranks: vec![2],
        }
    }

    fn setup(seed: u64, names: &[&str]) -> (AdapterEngine, ModelServer, Rng) {
        let mut rng = Rng::new(seed);
        let base = BaseModel::random(&tiny_cfg(), &mut rng);
        let mut eng = AdapterEngine::new(base);
        for name in names {
            eng.attach(name, AdapterSpec::pissa(2), &mut rng).unwrap();
            for module in LINEARS {
                drift_factors(&mut eng, name, module, 0.05, &mut rng).unwrap();
            }
        }
        let server = ModelServer::new(&eng, ServeConfig::full_model()).unwrap();
        (eng, server, rng)
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("pissa_residency_{tag}"));
        std::fs::remove_dir_all(&dir).ok();
        dir
    }

    #[test]
    fn demote_spills_losslessly_and_cold_promote_restores_bitwise() {
        let (mut eng, mut srv, _) = setup(31, &["a", "b"]);
        let dir = tmp_dir("bitwise");
        let mut tiers = TierManager::new(usize::MAX, &dir);
        tiers.register_hot("a", &eng, &srv).unwrap();
        tiers.register_hot("b", &eng, &srv).unwrap();

        let before = eng.get("a").unwrap().clone();
        tiers.demote(&mut eng, &mut srv, "a").unwrap();
        assert_eq!(tiers.tier("a"), Some(Tier::Cold), "Exact policy drops to cold");
        assert!(eng.get("a").is_err() && !srv.serves_adapter("a"));

        let fails = tiers.ensure_resident(&mut eng, &mut srv, &["a".to_string()]);
        assert!(fails.is_empty(), "{fails:?}");
        assert_eq!(tiers.tier("a"), Some(Tier::Hot));
        assert!(srv.serves_adapter("a"));
        let after = eng.get("a").unwrap();
        for (k, t) in &before.factors {
            assert_eq!(t.data, after.factors[k].data, "factors.{k} changed across eviction");
        }
        for (k, t) in &before.frozen {
            assert_eq!(t.data, after.frozen[k].data, "frozen.{k} changed across eviction");
        }
        assert_eq!(tiers.counters().promotions, 1);
        assert_eq!(tiers.counters().cold_attaches, 1);
        assert!(tiers.attach_p95_s() > 0.0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn warm_roundtrip_is_bounded_then_stable() {
        let (mut eng, mut srv, _) = setup(32, &["w"]);
        let dir = tmp_dir("warm");
        let mut tiers = TierManager::new(usize::MAX, &dir);
        tiers.register_hot("w", &eng, &srv).unwrap();
        tiers.set_policy("w", DemotePolicy::Compressed).unwrap();

        let orig = eng.get("w").unwrap().clone();
        tiers.demote(&mut eng, &mut srv, "w").unwrap();
        assert_eq!(tiers.tier("w"), Some(Tier::Warm));
        // Warm NF4 bytes are a small fraction of the f32 footprint.
        let f32_bytes: usize = orig.frozen.values().map(|t| t.data.len() * 4).sum::<usize>()
            + orig.factors.values().map(|t| t.data.len() * 4).sum::<usize>()
            + orig.init_factors.values().map(|t| t.data.len() * 4).sum::<usize>();
        assert!(
            tiers.resident_bytes() * 100 <= f32_bytes * 20,
            "warm bytes {} vs f32 {f32_bytes}",
            tiers.resident_bytes()
        );

        let fails = tiers.ensure_resident(&mut eng, &mut srv, &["w".to_string()]);
        assert!(fails.is_empty(), "{fails:?}");
        let first = eng.get("w").unwrap().clone();
        // Bounded relative to the original (the pinned NF4 bound)…
        for (k, t) in &orig.factors {
            let rt = &first.factors[k];
            let num: f64 = t
                .data
                .iter()
                .zip(&rt.data)
                .map(|(a, b)| (f64::from(*a) - f64::from(*b)).powi(2))
                .sum::<f64>()
                .sqrt();
            let den: f64 =
                t.data.iter().map(|a| f64::from(*a).powi(2)).sum::<f64>().sqrt().max(1e-30);
            assert!(num / den <= WARM_NF4_REL_TOL, "factors.{k} rel err {}", num / den);
        }
        // …and a second warm round trip is bitwise stable (NF4 fixed point).
        tiers.demote(&mut eng, &mut srv, "w").unwrap();
        let fails = tiers.ensure_resident(&mut eng, &mut srv, &["w".to_string()]);
        assert!(fails.is_empty(), "{fails:?}");
        let second = eng.get("w").unwrap();
        for (k, t) in &first.factors {
            assert_eq!(t.data, second.factors[k].data, "warm round trip moved factors.{k}");
        }
        for (k, t) in &first.frozen {
            assert_eq!(t.data, second.frozen[k].data, "warm round trip moved frozen.{k}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn budget_evicts_lru_and_protects_the_working_set() {
        let (mut eng, mut srv, _) = setup(33, &["a", "b", "c"]);
        let dir = tmp_dir("budget");
        let per = eng.adapter_bytes("a").unwrap() + srv.adapter_delta_bytes("a");
        // Room for exactly two hot adapters.
        let mut tiers = TierManager::new(2 * per, &dir);
        for n in ["a", "b", "c"] {
            tiers.register_hot(n, &eng, &srv).unwrap();
        }
        // "a" is oldest; asking for "c" must evict it (not the wanted set).
        let f = tiers.ensure_resident(&mut eng, &mut srv, &["b".to_string()]);
        assert!(f.is_empty());
        let f = tiers.ensure_resident(&mut eng, &mut srv, &["c".to_string()]);
        assert!(f.is_empty());
        assert_eq!(tiers.tier("a"), Some(Tier::Cold), "LRU victim");
        assert_eq!(tiers.tier("b"), Some(Tier::Hot));
        assert_eq!(tiers.tier("c"), Some(Tier::Hot));
        assert!(tiers.resident_bytes() <= tiers.budget_bytes());
        // Miss on "a" brings it back and evicts the now-oldest "b".
        let f = tiers.ensure_resident(&mut eng, &mut srv, &["a".to_string()]);
        assert!(f.is_empty());
        assert_eq!(tiers.tier("a"), Some(Tier::Hot));
        assert_eq!(tiers.tier("b"), Some(Tier::Cold));
        assert!(tiers.resident_bytes() <= tiers.budget_bytes());
        let table = tiers.tier_table();
        assert_eq!(table[0], ("hot", 2, tiers.resident_bytes()));
        assert_eq!(table[2].1, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sync_hits_touches_only_grown_counters() {
        let (mut eng, mut srv, _) = setup(34, &["a", "b"]);
        let dir = tmp_dir("hits");
        let per = eng.adapter_bytes("a").unwrap() + srv.adapter_delta_bytes("a");
        let mut tiers = TierManager::new(per, &dir);
        tiers.register_hot("a", &eng, &srv).unwrap();
        tiers.register_hot("b", &eng, &srv).unwrap();
        // Serving layer reports traffic on "a" only → "b" is the LRU
        // victim when the budget (one adapter) is enforced.
        tiers.ensure_resident(&mut eng, &mut srv, &[]); // advance the clock
        let mut hits = BTreeMap::new();
        hits.insert("a".to_string(), 3usize);
        tiers.sync_hits(&hits);
        let f = tiers.ensure_resident(&mut eng, &mut srv, &[]);
        assert!(f.is_empty());
        assert_eq!(tiers.tier("a"), Some(Tier::Hot));
        assert_eq!(tiers.tier("b"), Some(Tier::Cold));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unregistered_wanted_names_are_ignored() {
        let (mut eng, mut srv, _) = setup(35, &["a"]);
        let dir = tmp_dir("ignore");
        let mut tiers = TierManager::new(usize::MAX, &dir);
        tiers.register_hot("a", &eng, &srv).unwrap();
        let f = tiers.ensure_resident(&mut eng, &mut srv, &["ghost".to_string()]);
        assert!(f.is_empty(), "unregistered names are not promotion failures");
        assert_eq!(tiers.tier("ghost"), None);
    }
}
