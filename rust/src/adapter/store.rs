//! Binary checkpoint format for adapters, full parameter sets, and
//! optimizer state. No serde offline, so we use a simple self-describing
//! little-endian container:
//!
//!   magic "PISSACKP" | version u32 | n_entries u32
//!   per entry: name_len u32 | name bytes | rows u64 | cols u64 | f32 data
//!
//! The same container stores NF4 tensors (as an entry pair
//! `<name>.codes` (u8 payload, rows=len, cols=0 sentinel) and
//! `<name>.scales`).

use crate::linalg::Mat;
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"PISSACKP";
const VERSION: u32 = 1;

/// A named collection of matrices (and raw byte blobs).
#[derive(Default, Debug)]
pub struct Checkpoint {
    pub mats: BTreeMap<String, Mat>,
    pub blobs: BTreeMap<String, Vec<u8>>,
}

impl Checkpoint {
    pub fn new() -> Checkpoint {
        Checkpoint::default()
    }

    pub fn put(&mut self, name: &str, m: Mat) {
        self.mats.insert(name.to_string(), m);
    }

    pub fn put_blob(&mut self, name: &str, bytes: Vec<u8>) {
        self.blobs.insert(name.to_string(), bytes);
    }

    pub fn get(&self, name: &str) -> anyhow::Result<&Mat> {
        self.mats
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("checkpoint missing tensor '{name}'"))
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&VERSION.to_le_bytes())?;
        let n = (self.mats.len() + self.blobs.len()) as u32;
        f.write_all(&n.to_le_bytes())?;
        for (name, m) in &self.mats {
            write_entry_header(&mut f, name, m.rows as u64, m.cols as u64, 0)?;
            // f32 payload
            let bytes: Vec<u8> = m.data.iter().flat_map(|x| x.to_le_bytes()).collect();
            f.write_all(&bytes)?;
        }
        for (name, b) in &self.blobs {
            write_entry_header(&mut f, name, b.len() as u64, 0, 1)?;
            f.write_all(b)?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<Checkpoint> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == MAGIC, "not a pissa checkpoint: {path:?}");
        let version = read_u32(&mut f)?;
        anyhow::ensure!(version == VERSION, "unsupported checkpoint version {version}");
        let n = read_u32(&mut f)?;
        let mut ckp = Checkpoint::new();
        for _ in 0..n {
            let name_len = read_u32(&mut f)? as usize;
            let mut name_bytes = vec![0u8; name_len];
            f.read_exact(&mut name_bytes)?;
            let name = String::from_utf8(name_bytes)?;
            let rows = read_u64(&mut f)? as usize;
            let cols = read_u64(&mut f)? as usize;
            let kind = read_u32(&mut f)?;
            match kind {
                0 => {
                    let mut buf = vec![0u8; rows * cols * 4];
                    f.read_exact(&mut buf)?;
                    let data: Vec<f32> = buf
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect();
                    ckp.mats.insert(name, Mat::from_vec(rows, cols, data));
                }
                1 => {
                    let mut buf = vec![0u8; rows];
                    f.read_exact(&mut buf)?;
                    ckp.blobs.insert(name, buf);
                }
                k => anyhow::bail!("unknown entry kind {k}"),
            }
        }
        Ok(ckp)
    }
}

fn write_entry_header<W: Write>(
    f: &mut W,
    name: &str,
    rows: u64,
    cols: u64,
    kind: u32,
) -> anyhow::Result<()> {
    f.write_all(&(name.len() as u32).to_le_bytes())?;
    f.write_all(name.as_bytes())?;
    f.write_all(&rows.to_le_bytes())?;
    f.write_all(&cols.to_le_bytes())?;
    f.write_all(&kind.to_le_bytes())?;
    Ok(())
}

fn read_u32<R: Read>(f: &mut R) -> anyhow::Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(f: &mut R) -> anyhow::Result<u64> {
    let mut b = [0u8; 8];
    f.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn save_load_roundtrip() {
        let mut rng = Rng::new(100);
        let mut ckp = Checkpoint::new();
        ckp.put("layer0.a", Mat::randn(8, 4, 0.0, 1.0, &mut rng));
        ckp.put("layer0.b", Mat::randn(4, 8, 0.0, 1.0, &mut rng));
        ckp.put_blob("meta", b"{\"rank\":4}".to_vec());
        let dir = std::env::temp_dir().join("pissa_test_ckp");
        let path = dir.join("test.ckpt");
        ckp.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.mats.len(), 2);
        assert_eq!(back.get("layer0.a").unwrap().data, ckp.get("layer0.a").unwrap().data);
        assert_eq!(back.blobs["meta"], ckp.blobs["meta"]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir().join("pissa_test_ckp2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bogus.ckpt");
        std::fs::write(&path, b"NOTAPISSACHECKPOINT").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_tensor_error_names_it() {
        let ckp = Checkpoint::new();
        let err = ckp.get("nope").unwrap_err().to_string();
        assert!(err.contains("nope"));
    }
}
