//! Binary checkpoint format for adapters, full parameter sets, and
//! optimizer state. No serde offline, so we use a simple self-describing
//! little-endian container:
//!
//!   magic "PISSACKP" | version u32 | n_entries u32
//!   per entry: name_len u32 | name bytes | rows u64 | cols u64 | kind u32
//!              | payload
//!
//! Entry kinds: 0 = f32 matrix (payload rows·cols·4 bytes), 1 = raw byte
//! blob (payload `rows` bytes, cols = 0 sentinel), 2 = AdapterSpec string
//! (payload `rows` bytes). Any future kind MUST store its payload byte
//! length in `rows` so old loaders can skip it.
//!
//! Version history:
//! * v1 — mats + blobs only.
//! * v2 — adds the spec-metadata entry (`__spec__`, kind 2): a saved
//!   adapter records the `AdapterSpec` that produced it. The loader
//!   accepts v1 files (spec defaults to `None`) and skips entries with
//!   unknown reserved names (`__*`) or unknown kinds instead of erroring.
//!
//! The same container stores NF4 tensors (as an entry pair
//! `<name>.codes` (u8 payload, rows=len, cols=0 sentinel) and
//! `<name>.scales`).

use super::spec::AdapterSpec;
use crate::linalg::Mat;
use crate::model::params::Tensor;
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"PISSACKP";
const VERSION: u32 = 2;

const KIND_MAT: u32 = 0;
const KIND_BLOB: u32 = 1;
const KIND_SPEC: u32 = 2;

/// Reserved entry name carrying the serialized `AdapterSpec`.
const SPEC_ENTRY: &str = "__spec__";

/// A named collection of matrices (and raw byte blobs), optionally
/// stamped with the `AdapterSpec` that produced the stored adapter.
#[derive(Default, Debug)]
pub struct Checkpoint {
    pub mats: BTreeMap<String, Mat>,
    pub blobs: BTreeMap<String, Vec<u8>>,
    /// How the stored adapter was made (v2 files; `None` for v1).
    pub spec: Option<AdapterSpec>,
}

/// Encode a tensor shape as the `.shape` sidecar blob.
pub fn shape_blob(shape: &[usize]) -> Vec<u8> {
    shape.iter().flat_map(|&d| (d as u64).to_le_bytes()).collect()
}

/// Decode a `.shape` sidecar blob.
pub fn blob_shape(b: &[u8]) -> Vec<usize> {
    b.chunks_exact(8)
        .map(|c| u64::from_le_bytes(c.try_into().unwrap()) as usize)
        .collect()
}

impl Checkpoint {
    pub fn new() -> Checkpoint {
        Checkpoint::default()
    }

    pub fn put(&mut self, name: &str, m: Mat) {
        assert!(!name.starts_with("__"), "'__'-prefixed names are reserved (got '{name}')");
        self.mats.insert(name.to_string(), m);
    }

    pub fn put_blob(&mut self, name: &str, bytes: Vec<u8>) {
        assert!(!name.starts_with("__"), "'__'-prefixed names are reserved (got '{name}')");
        self.blobs.insert(name.to_string(), bytes);
    }

    /// Store an N-D tensor as a flat column matrix plus a `.shape` blob.
    pub fn put_tensor(&mut self, name: &str, t: &Tensor) {
        self.put(name, Mat::from_vec(t.numel(), 1, t.data.clone()));
        self.put_blob(&format!("{name}.shape"), shape_blob(&t.shape));
    }

    pub fn get(&self, name: &str) -> anyhow::Result<&Mat> {
        self.mats
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("checkpoint missing tensor '{name}'"))
    }

    /// Recover a tensor stored with [`Checkpoint::put_tensor`].
    pub fn get_tensor(&self, name: &str) -> anyhow::Result<Tensor> {
        let m = self.get(name)?;
        let shape_bytes = self
            .blobs
            .get(&format!("{name}.shape"))
            .ok_or_else(|| anyhow::anyhow!("checkpoint missing '{name}.shape'"))?;
        let shape = blob_shape(shape_bytes);
        anyhow::ensure!(
            shape.iter().product::<usize>() == m.data.len(),
            "'{name}': shape {shape:?} does not match {} stored elements",
            m.data.len()
        );
        Ok(Tensor { shape, data: m.data.clone() })
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&VERSION.to_le_bytes())?;
        let n = (self.mats.len() + self.blobs.len() + usize::from(self.spec.is_some())) as u32;
        f.write_all(&n.to_le_bytes())?;
        for (name, m) in &self.mats {
            write_entry_header(&mut f, name, m.rows as u64, m.cols as u64, KIND_MAT)?;
            // f32 payload
            let bytes: Vec<u8> = m.data.iter().flat_map(|x| x.to_le_bytes()).collect();
            f.write_all(&bytes)?;
        }
        for (name, b) in &self.blobs {
            write_entry_header(&mut f, name, b.len() as u64, 0, KIND_BLOB)?;
            f.write_all(b)?;
        }
        if let Some(spec) = &self.spec {
            let text = spec.to_string().into_bytes();
            write_entry_header(&mut f, SPEC_ENTRY, text.len() as u64, 0, KIND_SPEC)?;
            f.write_all(&text)?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<Checkpoint> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == MAGIC, "not a pissa checkpoint: {path:?}");
        let version = read_u32(&mut f)?;
        anyhow::ensure!(
            (1..=VERSION).contains(&version),
            "unsupported checkpoint version {version} (this build reads 1..={VERSION})"
        );
        let n = read_u32(&mut f)?;
        let mut ckp = Checkpoint::new();
        for _ in 0..n {
            let name_len = read_u32(&mut f)? as usize;
            let mut name_bytes = vec![0u8; name_len];
            f.read_exact(&mut name_bytes)?;
            let name = String::from_utf8(name_bytes)?;
            let rows = read_u64(&mut f)? as usize;
            let cols = read_u64(&mut f)? as usize;
            let kind = read_u32(&mut f)?;
            // Payload size is derivable for every kind: f32 matrices use
            // rows·cols·4 bytes, everything else stores its byte length
            // in `rows` (a convention future kinds must keep).
            let payload_len = if kind == KIND_MAT { rows * cols * 4 } else { rows };
            let mut buf = vec![0u8; payload_len];
            f.read_exact(&mut buf)?;
            if name.starts_with("__") {
                // Reserved namespace. The only entry this build knows is
                // the spec; anything else is skipped (writers reject
                // user-supplied '__' names, so nothing user-visible is
                // lost on a round-trip).
                if name == SPEC_ENTRY && kind == KIND_SPEC {
                    let text = String::from_utf8(buf)?;
                    ckp.spec = Some(AdapterSpec::parse(&text)?);
                }
                continue;
            }
            match kind {
                KIND_MAT => {
                    let data: Vec<f32> = buf
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect();
                    ckp.mats.insert(name, Mat::from_vec(rows, cols, data));
                }
                KIND_BLOB => {
                    ckp.blobs.insert(name, buf);
                }
                // KIND_SPEC under a non-reserved name, or a future kind:
                // skipped for forward compatibility.
                _ => {}
            }
        }
        Ok(ckp)
    }
}

fn write_entry_header<W: Write>(
    f: &mut W,
    name: &str,
    rows: u64,
    cols: u64,
    kind: u32,
) -> anyhow::Result<()> {
    f.write_all(&(name.len() as u32).to_le_bytes())?;
    f.write_all(name.as_bytes())?;
    f.write_all(&rows.to_le_bytes())?;
    f.write_all(&cols.to_le_bytes())?;
    f.write_all(&kind.to_le_bytes())?;
    Ok(())
}

fn read_u32<R: Read>(f: &mut R) -> anyhow::Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(f: &mut R) -> anyhow::Result<u64> {
    let mut b = [0u8; 8];
    f.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn save_load_roundtrip() {
        let mut rng = Rng::new(100);
        let mut ckp = Checkpoint::new();
        ckp.put("layer0.a", Mat::randn(8, 4, 0.0, 1.0, &mut rng));
        ckp.put("layer0.b", Mat::randn(4, 8, 0.0, 1.0, &mut rng));
        ckp.put_blob("meta", b"{\"rank\":4}".to_vec());
        let dir = std::env::temp_dir().join("pissa_test_ckp");
        let path = dir.join("test.ckpt");
        ckp.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.mats.len(), 2);
        assert_eq!(back.get("layer0.a").unwrap().data, ckp.get("layer0.a").unwrap().data);
        assert_eq!(back.blobs["meta"], ckp.blobs["meta"]);
        assert_eq!(back.spec, None);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn spec_metadata_roundtrips() {
        let mut ckp = Checkpoint::new();
        ckp.spec = Some(AdapterSpec::pissa(8).targets(&["q", "v"]).target_rank("q", 16));
        ckp.put("a", Mat::zeros(2, 2));
        let dir = std::env::temp_dir().join("pissa_test_ckp_spec");
        let path = dir.join("spec.ckpt");
        ckp.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.spec, ckp.spec);
        assert_eq!(back.mats.len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn tensor_helpers_roundtrip() {
        let mut rng = Rng::new(101);
        let t = Tensor::randn(&[2, 3, 4], 1.0, &mut rng);
        let mut ckp = Checkpoint::new();
        ckp.put_tensor("stack", &t);
        let dir = std::env::temp_dir().join("pissa_test_ckp_tensor");
        let path = dir.join("t.ckpt");
        ckp.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap().get_tensor("stack").unwrap();
        assert_eq!(back.shape, t.shape);
        assert_eq!(back.data, t.data);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn v1_files_still_load() {
        // Hand-craft a v1 container: one 1x2 mat, one blob, no spec entry.
        let dir = std::env::temp_dir().join("pissa_test_ckp_v1");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("v1.ckpt");
        let mut bytes: Vec<u8> = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&1u32.to_le_bytes()); // version 1
        bytes.extend_from_slice(&2u32.to_le_bytes()); // 2 entries
        // mat "m": rows=1 cols=2 kind=0, payload [1.5, -2.0]
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(b"m");
        bytes.extend_from_slice(&1u64.to_le_bytes());
        bytes.extend_from_slice(&2u64.to_le_bytes());
        bytes.extend_from_slice(&0u32.to_le_bytes());
        bytes.extend_from_slice(&1.5f32.to_le_bytes());
        bytes.extend_from_slice(&(-2.0f32).to_le_bytes());
        // blob "b": rows=3 cols=0 kind=1, payload "abc"
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(b"b");
        bytes.extend_from_slice(&3u64.to_le_bytes());
        bytes.extend_from_slice(&0u64.to_le_bytes());
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(b"abc");
        std::fs::write(&path, &bytes).unwrap();

        let ckp = Checkpoint::load(&path).unwrap();
        assert_eq!(ckp.spec, None);
        assert_eq!(ckp.get("m").unwrap().data, vec![1.5, -2.0]);
        assert_eq!(ckp.blobs["b"], b"abc".to_vec());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unknown_reserved_entries_and_kinds_are_skipped() {
        let dir = std::env::temp_dir().join("pissa_test_ckp_skip");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("fwd.ckpt");
        let mut bytes: Vec<u8> = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&2u32.to_le_bytes()); // version 2
        bytes.extend_from_slice(&3u32.to_le_bytes()); // 3 entries
        // entry 1: unknown reserved blob "__future__" (kind 1, 4 bytes)
        bytes.extend_from_slice(&10u32.to_le_bytes());
        bytes.extend_from_slice(b"__future__");
        bytes.extend_from_slice(&4u64.to_le_bytes());
        bytes.extend_from_slice(&0u64.to_le_bytes());
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(b"\x01\x02\x03\x04");
        // entry 2: unknown kind 7 ("exotic", 5 payload bytes in rows)
        bytes.extend_from_slice(&6u32.to_le_bytes());
        bytes.extend_from_slice(b"exotic");
        bytes.extend_from_slice(&5u64.to_le_bytes());
        bytes.extend_from_slice(&0u64.to_le_bytes());
        bytes.extend_from_slice(&7u32.to_le_bytes());
        bytes.extend_from_slice(b"hello");
        // entry 3: a normal mat that must survive
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(b"m");
        bytes.extend_from_slice(&1u64.to_le_bytes());
        bytes.extend_from_slice(&1u64.to_le_bytes());
        bytes.extend_from_slice(&0u32.to_le_bytes());
        bytes.extend_from_slice(&3.25f32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();

        let ckp = Checkpoint::load(&path).unwrap();
        assert!(ckp.blobs.is_empty(), "reserved entry must be skipped");
        assert_eq!(ckp.mats.len(), 1);
        assert_eq!(ckp.get("m").unwrap().data, vec![3.25]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir().join("pissa_test_ckp2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bogus.ckpt");
        std::fs::write(&path, b"NOTAPISSACHECKPOINT").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn future_version_rejected() {
        let dir = std::env::temp_dir().join("pissa_test_ckp3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("v99.ckpt");
        let mut bytes: Vec<u8> = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&99u32.to_le_bytes());
        bytes.extend_from_slice(&0u32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_tensor_error_names_it() {
        let ckp = Checkpoint::new();
        let err = ckp.get("nope").unwrap_err().to_string();
        assert!(err.contains("nope"));
    }
}
