//! PiSSA → LoRA adapter conversion (Appendix C, Eq. 9–10).
//!
//! After training, the model weight is W + ΔW = W_res + A'B'. Sharing A'
//! and B' directly would force users to re-run SVD on the base model; the
//! paper instead shares the *equivalent LoRA adapter*
//!     ΔW = A'B' − AB = [A' | A] · [B' ; −B]  (ΔA ∈ R^{m×2r}, ΔB ∈ R^{2r×n})
//! which plugs into the *original* W without any decomposition.

use super::init::AdapterInit;
use crate::linalg::{matmul, Mat};

/// A plain LoRA-style delta adapter: W_new = W_orig + ΔA·ΔB.
#[derive(Clone, Debug)]
pub struct LoraDelta {
    pub da: Mat, // m × 2r
    pub db: Mat, // 2r × n
}

impl LoraDelta {
    /// Materialize ΔW = ΔA·ΔB.
    pub fn delta(&self) -> Mat {
        matmul(&self.da, &self.db)
    }
}

/// Build the equivalent LoRA adapter from the *initial* PiSSA factors
/// (A, B) and the *trained* factors (A', B'): ΔA = [A' | A], ΔB = [B'; −B].
pub fn pissa_to_lora(init_a: &Mat, init_b: &Mat, trained_a: &Mat, trained_b: &Mat) -> LoraDelta {
    assert_eq!(init_a.rows, trained_a.rows);
    assert_eq!(init_b.cols, trained_b.cols);
    assert_eq!(init_a.cols, init_b.rows);
    assert_eq!(trained_a.cols, trained_b.rows);
    let m = init_a.rows;
    let n = init_b.cols;
    let r0 = trained_a.cols;
    let r1 = init_a.cols;

    // ΔA = [A' | A]
    let mut da = Mat::zeros(m, r0 + r1);
    for i in 0..m {
        da.row_mut(i)[..r0].copy_from_slice(trained_a.row(i));
        da.row_mut(i)[r0..].copy_from_slice(init_a.row(i));
    }
    // ΔB = [B' ; −B]
    let mut db = Mat::zeros(r0 + r1, n);
    for k in 0..r0 {
        db.row_mut(k).copy_from_slice(trained_b.row(k));
    }
    for k in 0..r1 {
        for (dst, &src) in db.row_mut(r0 + k).iter_mut().zip(init_b.row(k)) {
            *dst = -src;
        }
    }
    LoraDelta { da, db }
}

/// Merge a trained adapter into a dense weight: W_merged = base + A'B'.
/// (Deployment path: "integration of trainable matrices with the
/// pre-trained weights upon deployment", paper §3.)
pub fn merge(init: &AdapterInit) -> Mat {
    init.effective()
}

/// Apply a converted LoRA delta to the original dense W.
pub fn apply_delta(w_orig: &Mat, delta: &LoraDelta) -> Mat {
    w_orig.add(&delta.delta())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter::init::pissa;
    use crate::util::rng::Rng;

    #[test]
    fn conversion_is_exact() {
        // Simulate training: perturb A and B, then check that
        // W_orig + ΔA·ΔB == W_res + A'B' exactly (Eq. 9–10).
        let mut rng = Rng::new(90);
        let w = Mat::randn(24, 20, 0.0, 0.5, &mut rng);
        let init = pissa(&w, 4, None, &mut rng);
        let mut a_t = init.a.clone();
        let mut b_t = init.b.clone();
        // "train": random drift
        for x in a_t.data.iter_mut() {
            *x += 0.1 * rng.normal_f32(0.0, 1.0);
        }
        for x in b_t.data.iter_mut() {
            *x += 0.1 * rng.normal_f32(0.0, 1.0);
        }

        let finetuned = init.base.add(&matmul(&a_t, &b_t)); // W_res + A'B'
        let delta = pissa_to_lora(&init.a, &init.b, &a_t, &b_t);
        let via_lora = apply_delta(&w, &delta); // W + ΔA·ΔB

        let err = finetuned.sub(&via_lora).fro() / finetuned.fro();
        assert!(err < 1e-5, "conversion err={err}");
        // Shapes: ΔA is m×2r, ΔB is 2r×n.
        assert_eq!(delta.da.cols, 8);
        assert_eq!(delta.db.rows, 8);
    }

    #[test]
    fn zero_training_gives_zero_delta() {
        let mut rng = Rng::new(91);
        let w = Mat::randn(16, 16, 0.0, 0.5, &mut rng);
        let init = pissa(&w, 4, None, &mut rng);
        let delta = pissa_to_lora(&init.a, &init.b, &init.a, &init.b);
        assert!(delta.delta().fro() < 1e-5);
    }

    #[test]
    fn merge_matches_effective() {
        let mut rng = Rng::new(92);
        let w = Mat::randn(12, 10, 0.0, 0.5, &mut rng);
        let init = pissa(&w, 3, None, &mut rng);
        assert_eq!(merge(&init).data, init.effective().data);
    }
}
