//! Adapter initialization — the paper's contribution.
//!
//! Implements every initialization compared in the paper:
//!   * **PiSSA** (Eq. 2–4): A = U[:, :r]·S[:r]^{1/2}, B = S[:r]^{1/2}·V[:, :r]ᵀ,
//!     residual W_res = W − AB frozen.
//!   * **LoRA** (Hu et al.): A ~ N(0, 1/√r)… actually Kaiming-uniform in the
//!     reference impl; we use N(0, 0.02) per the paper's "Gaussian" wording,
//!     B = 0, base = W frozen.
//!   * **LoftQ** (Li et al., Eq. 14–15 + alternating): SVD of the
//!     *quantization-error* matrix, T alternating iterations.
//!   * **QPiSSA-T-iter** (Algorithm 1): alternate SVD of W − nf4(W_res).
//!   * **Component ablation** (Appendix A): principal / medium / minor
//!     singular-triplet windows.
//!
//! All of them produce the same `AdapterInit { base, a, b }` shape so the
//! training stack is strategy-agnostic — exactly the paper's point that
//! PiSSA is a drop-in replacement for LoRA.

use crate::linalg::{matmul, rsvd, svd, Mat, Svd};
use crate::quant::nf4::nf4_roundtrip;
use crate::util::rng::Rng;

/// Which initialization strategy to use (paper's comparison set).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// Full fine-tuning (no adapter; the whole W is trainable).
    FullFt,
    /// LoRA: Gaussian A, zero B, frozen W.
    Lora,
    /// PiSSA: principal singular triplets in the adapter, residual frozen.
    Pissa,
    /// QLoRA: LoRA + NF4-quantized frozen base.
    QLora,
    /// QPiSSA: PiSSA + NF4-quantized frozen residual (T alternating iters).
    QPissa,
    /// LoftQ: adapter holds principal components of the quantization error.
    LoftQ,
}

impl Strategy {
    pub fn parse(s: &str) -> anyhow::Result<Strategy> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "full" | "full-ft" | "fullft" => Strategy::FullFt,
            "lora" => Strategy::Lora,
            "pissa" => Strategy::Pissa,
            "qlora" => Strategy::QLora,
            "qpissa" => Strategy::QPissa,
            "loftq" => Strategy::LoftQ,
            other => anyhow::bail!("unknown strategy '{other}'"),
        })
    }
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::FullFt => "full-ft",
            Strategy::Lora => "lora",
            Strategy::Pissa => "pissa",
            Strategy::QLora => "qlora",
            Strategy::QPissa => "qpissa",
            Strategy::LoftQ => "loftq",
        }
    }
    /// Does this strategy NF4-quantize its frozen base?
    pub fn quantized(&self) -> bool {
        matches!(self, Strategy::QLora | Strategy::QPissa | Strategy::LoftQ)
    }
}

/// Result of initializing one linear layer's adapter.
#[derive(Clone, Debug)]
pub struct AdapterInit {
    /// Frozen base matrix (W, W_res, or its NF4 round trip for Q-strategies).
    pub base: Mat,
    /// Trainable A (m×r).
    pub a: Mat,
    /// Trainable B (r×n).
    pub b: Mat,
}

impl AdapterInit {
    /// Effective weight seen by the forward pass: base + A·B.
    pub fn effective(&self) -> Mat {
        self.base.add(&matmul(&self.a, &self.b))
    }
}

/// Which SVD window to take triplets from (Appendix A ablation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Window {
    Principal,
    Medium,
    Minor,
}

impl Window {
    pub fn parse(s: &str) -> anyhow::Result<Window> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "principal" => Window::Principal,
            "medium" => Window::Medium,
            "minor" => Window::Minor,
            other => anyhow::bail!("unknown window '{other}' (principal|medium|minor)"),
        })
    }
    pub fn name(&self) -> &'static str {
        match self {
            Window::Principal => "principal",
            Window::Medium => "medium",
            Window::Minor => "minor",
        }
    }
}

/// Factor a rank-r window of an SVD into (A, B) per Eq. 2–3:
/// A = U·S^{1/2}, B = S^{1/2}·Vᵀ over columns [lo, lo+r).
///
/// A window starting at/after the end of the spectrum (e.g. a minor-window
/// request against a rank-truncated decomposition of a small matrix) is a
/// caller bug in debug builds; release builds clamp and return empty
/// (m×0 / 0×n) factors instead of panicking on the slice.
pub(crate) fn window_factors(dec: &Svd, lo: usize, r: usize) -> (Mat, Mat) {
    let k = dec.s.len();
    debug_assert!(
        r == 0 || lo < k,
        "window [{lo}, {lo}+{r}) starts beyond the {k}-long spectrum"
    );
    let lo = lo.min(k);
    let hi = (lo + r).min(k);
    let sqrt_s: Vec<f32> = dec.s[lo..hi].iter().map(|&x| x.max(0.0).sqrt()).collect();
    let mut a = dec.u.cols_range(lo, hi);
    a.scale_cols(&sqrt_s);
    let mut b = dec.vt.rows_range(lo, hi);
    b.scale_rows(&sqrt_s);
    (a, b)
}

/// PiSSA init (Eq. 2–4), with a choice of exact or fast (randomized) SVD.
/// `niter = None` means exact Jacobi SVD (the paper's "∞"); `Some(t)` uses
/// the Halko fast SVD with t subspace iterations (paper's Table 4 knob).
pub fn pissa(w: &Mat, r: usize, niter: Option<usize>, rng: &mut Rng) -> AdapterInit {
    let dec = match niter {
        None => svd(w),
        Some(t) => rsvd(w, r, t, rng),
    };
    let (a, b) = window_factors(&dec, 0, r);
    // W_res = W − A·B (exact residual; for rsvd this absorbs the sketch
    // error into the frozen part, keeping base + AB == W exactly).
    let base = w.sub(&matmul(&a, &b));
    AdapterInit { base, a, b }
}

/// Appendix-A ablation: adapter from the principal / medium / minor window.
pub fn pissa_window(w: &Mat, r: usize, window: Window) -> AdapterInit {
    let dec = svd(w);
    let k = dec.s.len();
    let lo = match window {
        Window::Principal => 0,
        Window::Medium => (k.saturating_sub(r)) / 2,
        Window::Minor => k.saturating_sub(r),
    };
    let (a, b) = window_factors(&dec, lo, r);
    let base = w.sub(&matmul(&a, &b));
    AdapterInit { base, a, b }
}

/// LoRA init: A ~ N(0, 0.02), B = 0, frozen base = W. AB = 0 at start so
/// the injection does not change the model output (paper §1).
pub fn lora(w: &Mat, r: usize, rng: &mut Rng) -> AdapterInit {
    let a = Mat::randn(w.rows, r, 0.0, 0.02, rng);
    let b = Mat::zeros(r, w.cols);
    AdapterInit { base: w.clone(), a, b }
}

/// QLoRA init: LoRA adapters over an NF4-quantized frozen base.
pub fn qlora(w: &Mat, r: usize, rng: &mut Rng) -> AdapterInit {
    let mut init = lora(w, r, rng);
    init.base = nf4_roundtrip(&init.base);
    init
}

/// Rank-r factors of `target` via fast SVD with `niter` subspace
/// iterations, or exact Jacobi SVD when `niter` is `None`.
fn rank_factors(target: &Mat, r: usize, niter: Option<usize>, rng: &mut Rng) -> (Mat, Mat) {
    let dec = match niter {
        None => svd(target),
        Some(t) => rsvd(target, r, t, rng),
    };
    window_factors(&dec, 0, r)
}

/// QPiSSA-T-iters (Algorithm 1). T = 1 is plain PiSSA + quantize(W_res).
/// T ≥ 2 alternates: A,B ← SVDr(W − nf4(W_res)); W_res ← W − AB.
/// Uses the legacy fast-SVD setting (niter = 4); see [`qpissa_with`].
pub fn qpissa(w: &Mat, r: usize, iters: usize, rng: &mut Rng) -> AdapterInit {
    qpissa_with(w, r, iters, Some(4), rng)
}

/// QPiSSA with an explicit SVD quality knob: `niter = Some(t)` uses the
/// Halko fast SVD with t subspace iterations per alternation, `None`
/// uses exact Jacobi SVD.
pub fn qpissa_with(
    w: &Mat,
    r: usize,
    iters: usize,
    niter: Option<usize>,
    rng: &mut Rng,
) -> AdapterInit {
    assert!(iters >= 1);
    let mut init = pissa(w, r, niter, rng);
    let mut w_res = init.base.clone();
    for _t in 1..iters {
        let target = w.sub(&nf4_roundtrip(&w_res));
        let (a, b) = rank_factors(&target, r, niter, rng);
        w_res = w.sub(&matmul(&a, &b));
        init.a = a;
        init.b = b;
    }
    init.base = nf4_roundtrip(&w_res);
    AdapterInit { base: init.base, a: init.a, b: init.b }
}

/// LoftQ-T-iters (Eq. 11, 14–15): adapter holds the principal components
/// of the *quantization error*; A, B start from SVD of W − nf4(Q).
/// Uses the legacy fast-SVD setting (niter = 4); see [`loftq_with`].
pub fn loftq(w: &Mat, r: usize, iters: usize, rng: &mut Rng) -> AdapterInit {
    loftq_with(w, r, iters, Some(4), rng)
}

/// LoftQ with an explicit SVD quality knob (see [`qpissa_with`]).
pub fn loftq_with(
    w: &Mat,
    r: usize,
    iters: usize,
    niter: Option<usize>,
    rng: &mut Rng,
) -> AdapterInit {
    assert!(iters >= 1);
    // t = 1: Q = nf4(W), err = W − Q, (A,B) = SVD_r(err).
    let mut q = nf4_roundtrip(w);
    let mut a = Mat::zeros(w.rows, r);
    let mut b = Mat::zeros(r, w.cols);
    for _t in 0..iters {
        let err = w.sub(&q);
        let (na, nb) = rank_factors(&err, r, niter, rng);
        a = na;
        b = nb;
        // Re-quantize the residual after removing the adapter part.
        q = nf4_roundtrip(&w.sub(&matmul(&a, &b)));
    }
    AdapterInit { base: q, a, b }
}

/// Dispatch by strategy (FullFt returns the identity decomposition:
/// base = 0, A·B = unused; callers treat FullFt specially).
///
/// Legacy entry point: the declarative path is
/// `AdapterSpec::init_matrix`, which is bit-identical to this dispatch
/// for equivalent configs (asserted in `rust/tests/adapter_api.rs`) and
/// additionally supports niter/window/alpha/targeting control.
#[deprecated(note = "build an AdapterSpec and call init_matrix instead")]
pub fn initialize(
    strategy: Strategy,
    w: &Mat,
    r: usize,
    iters: usize,
    rng: &mut Rng,
) -> AdapterInit {
    match strategy {
        Strategy::FullFt => AdapterInit {
            base: Mat::zeros(w.rows, w.cols),
            a: w.clone(),
            b: Mat::eye(w.cols),
        },
        Strategy::Lora => lora(w, r, rng),
        Strategy::Pissa => pissa(w, r, Some(4), rng),
        Strategy::QLora => qlora(w, r, rng),
        Strategy::QPissa => qpissa(w, r, iters, rng),
        Strategy::LoftQ => loftq(w, r, iters, rng),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{qlora_error, strategy_error};

    fn test_w(rng: &mut Rng) -> Mat {
        // A matrix with a decaying spectrum, like pre-trained weights:
        // random orthogonal-ish factors with power-law singular values.
        let m = 48;
        let n = 40;
        let u = Mat::randn(m, n, 0.0, 1.0, rng);
        let mut s: Vec<f32> = (0..n).map(|i| 1.0 / (1.0 + i as f32).powf(0.8)).collect();
        s[0] = 3.0; // a dominant direction
        let v = Mat::randn(n, n, 0.0, 1.0, rng);
        let qu = crate::linalg::qr::orthonormalize(&u);
        let qv = crate::linalg::qr::orthonormalize(&v);
        let mut us = qu;
        us.scale_cols(&s);
        matmul(&us, &qv.t())
    }

    #[test]
    fn pissa_preserves_w_exactly() {
        // Eq. 5: base + AB == W at init, bit-for-bit up to fp rounding.
        let mut rng = Rng::new(80);
        let w = test_w(&mut rng);
        for niter in [None, Some(2), Some(8)] {
            let init = pissa(&w, 8, niter, &mut rng);
            let err = init.effective().sub(&w).fro() / w.fro();
            assert!(err < 1e-5, "niter={niter:?} err={err}");
        }
    }

    #[test]
    fn lora_starts_at_w() {
        let mut rng = Rng::new(81);
        let w = test_w(&mut rng);
        let init = lora(&w, 8, &mut rng);
        assert_eq!(init.effective().sub(&w).fro(), 0.0); // AB = 0 exactly
        assert!(init.a.fro() > 0.0);
        assert_eq!(init.b.fro(), 0.0);
    }

    #[test]
    fn pissa_adapter_captures_principal_mass() {
        let mut rng = Rng::new(82);
        let w = test_w(&mut rng);
        let init = pissa(&w, 8, None, &mut rng);
        let ab = matmul(&init.a, &init.b);
        // ‖AB‖F should carry the top-8 singular mass, more than the residual.
        assert!(ab.fro() > init.base.fro(), "ab={} res={}", ab.fro(), init.base.fro());
    }

    #[test]
    fn qpissa_reduces_error_vs_qlora() {
        // The paper's headline quantization claim (Table 3).
        let mut rng = Rng::new(83);
        let w = test_w(&mut rng);
        let baseline = qlora_error(&w);
        let qp = qpissa(&w, 8, 1, &mut rng);
        // base is already the nf4 roundtrip; measure ‖W − (base + AB)‖_*.
        let err = crate::linalg::nuclear_norm(&w.sub(&qp.base.add(&matmul(&qp.a, &qp.b))));
        assert!(err < baseline, "qpissa={err} qlora={baseline}");
    }

    #[test]
    fn qpissa_more_iters_reduces_error() {
        // Appendix E: T=5 beats T=1.
        let mut rng = Rng::new(84);
        let w = test_w(&mut rng);
        let e1 = {
            let i = qpissa(&w, 6, 1, &mut rng);
            w.sub(&i.base.add(&matmul(&i.a, &i.b))).fro()
        };
        let e5 = {
            let i = qpissa(&w, 6, 5, &mut rng);
            w.sub(&i.base.add(&matmul(&i.a, &i.b))).fro()
        };
        assert!(e5 <= e1 * 1.01, "T=5 ({e5}) should beat T=1 ({e1})");
    }

    #[test]
    fn loftq_reduces_error_but_less_than_qpissa() {
        // Appendix F ordering: QLoRA > LoftQ > QPiSSA in error.
        let mut rng = Rng::new(85);
        let w = test_w(&mut rng);
        let baseline = qlora_error(&w);
        let lq = loftq(&w, 8, 5, &mut rng);
        let e_loftq =
            crate::linalg::nuclear_norm(&w.sub(&lq.base.add(&matmul(&lq.a, &lq.b))));
        let qp = qpissa(&w, 8, 5, &mut rng);
        let e_qpissa =
            crate::linalg::nuclear_norm(&w.sub(&qp.base.add(&matmul(&qp.a, &qp.b))));
        assert!(e_loftq < baseline, "loftq={e_loftq} qlora={baseline}");
        assert!(e_qpissa < e_loftq * 1.05, "qpissa={e_qpissa} loftq={e_loftq}");
    }

    #[test]
    fn windows_are_disjoint_quality() {
        // Appendix A: principal window approximates W best.
        let mut rng = Rng::new(86);
        let w = test_w(&mut rng);
        let pri = pissa_window(&w, 6, Window::Principal);
        let med = pissa_window(&w, 6, Window::Medium);
        let min = pissa_window(&w, 6, Window::Minor);
        let frob = |i: &AdapterInit| matmul(&i.a, &i.b).fro();
        assert!(frob(&pri) > frob(&med), "principal should carry most mass");
        assert!(frob(&med) > frob(&min) * 0.999);
        // all preserve W exactly
        for i in [&pri, &med, &min] {
            assert!(i.effective().sub(&w).fro() / w.fro() < 1e-5);
        }
    }

    #[test]
    fn strategy_parse_roundtrip() {
        for s in [
            Strategy::FullFt,
            Strategy::Lora,
            Strategy::Pissa,
            Strategy::QLora,
            Strategy::QPissa,
            Strategy::LoftQ,
        ] {
            assert_eq!(Strategy::parse(s.name()).unwrap(), s);
        }
        assert!(Strategy::parse("bogus").is_err());
    }

    // Regression for the out-of-bounds window: a start index at/after the
    // end of the spectrum used to panic on `dec.s[lo..hi]`. Debug builds
    // now flag the misuse loudly; release builds clamp to empty factors.
    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "starts beyond")]
    fn window_factors_out_of_range_asserts_in_debug() {
        let mut rng = Rng::new(88);
        let w = Mat::randn(6, 5, 0.0, 1.0, &mut rng);
        let dec = svd(&w); // spectrum length 5
        let _ = window_factors(&dec, 10, 3);
    }

    #[cfg(not(debug_assertions))]
    #[test]
    fn window_factors_out_of_range_clamps_in_release() {
        let mut rng = Rng::new(88);
        let w = Mat::randn(6, 5, 0.0, 1.0, &mut rng);
        let dec = svd(&w);
        let (a, b) = window_factors(&dec, 10, 3);
        assert_eq!((a.rows, a.cols), (6, 0));
        assert_eq!((b.rows, b.cols), (0, 5));
        // An empty window contributes nothing: A·B is all-zero.
        assert_eq!(matmul(&a, &b).fro(), 0.0);
    }

    #[test]
    fn window_parse_roundtrip() {
        for w in [Window::Principal, Window::Medium, Window::Minor] {
            assert_eq!(Window::parse(w.name()).unwrap(), w);
        }
        assert!(Window::parse("bogus").is_err());
    }

    #[test]
    fn strategy_error_helper_consistency() {
        let mut rng = Rng::new(87);
        let w = test_w(&mut rng);
        let init = pissa(&w, 8, Some(4), &mut rng);
        let ab = matmul(&init.a, &init.b);
        let via_helper = strategy_error(&w, &init.base, &ab);
        assert!(via_helper >= 0.0);
        assert!(via_helper < qlora_error(&w), "PiSSA should beat QLoRA error");
    }
}
