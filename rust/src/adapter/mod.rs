//! The paper's contribution: adapter initialization (PiSSA Eq. 2–4, LoRA,
//! QLoRA, QPiSSA Algorithm 1, LoftQ), the PiSSA→LoRA conversion of
//! Appendix C, and adapter/optimizer checkpointing.

pub mod convert;
pub mod init;
pub mod store;

pub use convert::{apply_delta, pissa_to_lora, LoraDelta};
pub use init::{initialize, lora, loftq, pissa, pissa_window, qlora, qpissa, AdapterInit, Strategy, Window};
pub use store::Checkpoint;
