//! The paper's contribution: adapter initialization (PiSSA Eq. 2–4, LoRA,
//! QLoRA, QPiSSA Algorithm 1, LoftQ), the declarative [`AdapterSpec`]
//! config surface, the multi-adapter [`AdapterEngine`] (hot-swap,
//! merge/unmerge, Appendix-C export over one frozen base), the
//! PiSSA→LoRA conversion of Appendix C, adapter/optimizer
//! checkpointing, and the hot/warm/cold residency tiering that serves
//! more registered tenants than fit in RAM.

pub mod convert;
pub mod engine;
pub mod init;
pub mod residency;
pub mod spec;
pub mod store;

pub use convert::{apply_delta, pissa_to_lora, LoraDelta};
pub use engine::{AdapterEngine, AdapterError, NamedAdapter};
pub use residency::{DemotePolicy, Tier, TierCounters, TierManager, WarmAdapter, WARM_NF4_REL_TOL};
pub use init::{
    lora, loftq, loftq_with, pissa, pissa_window, qlora, qpissa, qpissa_with, AdapterInit,
    Strategy, Window,
};
pub use spec::{AdapterSpec, TargetSpec};
pub use store::Checkpoint;
