//! `AdapterSpec` — the single declarative configuration surface for
//! adapter initialization.
//!
//! The paper's point is that PiSSA is a *drop-in* replacement for LoRA:
//! same architecture, one knob. The reference peft API expresses that as
//! one config object (`LoraConfig(init_lora_weights="pissa_niter_4",
//! target_modules=[...])`); this module is the rust-side equivalent.
//! A spec bundles:
//!
//! * `strategy` — full-ft / LoRA / PiSSA / QLoRA / QPiSSA / LoftQ,
//! * `rank` + optional per-module rank overrides,
//! * `alpha` — LoRA-style scaling (`scaling = alpha / rank`, folded
//!   √scaling into each factor so `base + A·B` needs no runtime knob),
//! * `niter` — fast-SVD subspace iterations (`None` = exact Jacobi SVD,
//!   the paper's "∞"),
//! * `iters` — QPiSSA/LoftQ alternation count (Algorithm 1's T),
//! * `window` — principal/medium/minor singular-triplet window
//!   (Appendix A ablation),
//! * `target_modules` — subset of the seven adapter-targeted linears.
//!
//! Specs round-trip through a compact string form (`parse`/`Display`)
//! for CLI use, and the same string is what the `PISSACKP` v2 checkpoint
//! container stores so a saved adapter records how it was made.

use super::init::{self, AdapterInit, Strategy, Window};
use crate::linalg::{matmul, Mat};
use crate::model::LINEARS;
use crate::quant::nf4_roundtrip;
use crate::util::rng::Rng;
use anyhow::Result;
use std::fmt;

/// Fast-SVD subspace iterations used by the legacy dispatch (and peft's
/// recommended `pissa_niter_4`).
pub const DEFAULT_NITER: usize = 4;
/// Default QPiSSA/LoftQ alternation count (paper §5.3/5.4 uses T=5).
pub const DEFAULT_ITERS: usize = 5;

/// One targeted module, with an optional rank override.
#[derive(Clone, Debug, PartialEq)]
pub struct TargetSpec {
    pub module: String,
    /// `None` → use the spec-level rank.
    pub rank: Option<usize>,
}

/// Declarative adapter configuration. Build with the strategy constructors
/// and chained setters:
///
/// `AdapterSpec::pissa(8).niter(4).targets(&["q", "v"])`
#[derive(Clone, Debug, PartialEq)]
pub struct AdapterSpec {
    pub strategy: Strategy,
    /// Default adapter rank (0 for full-ft, where it is meaningless).
    pub rank: usize,
    /// LoRA-style scaling numerator; `alpha == rank` ⇒ scaling 1 (the
    /// paper's protocol, and bit-identical to the legacy init path).
    pub alpha: f32,
    /// Fast-SVD subspace iterations; `None` = exact SVD.
    pub niter: Option<usize>,
    /// QPiSSA/LoftQ alternation count (Algorithm 1's T).
    pub iters: usize,
    /// Which singular-triplet window seeds the factors (Appendix A).
    pub window: Window,
    /// Targeted modules; empty = all seven `LINEARS`.
    pub targets: Vec<TargetSpec>,
}

fn default_niter(strategy: Strategy) -> Option<usize> {
    match strategy {
        // The SVD-based strategies all default to the legacy fast-SVD
        // setting (Halko, 4 subspace iterations).
        Strategy::Pissa | Strategy::QPissa | Strategy::LoftQ => Some(DEFAULT_NITER),
        _ => None,
    }
}

impl AdapterSpec {
    /// Base constructor; prefer the per-strategy shorthands below.
    pub fn new(strategy: Strategy, rank: usize) -> AdapterSpec {
        let rank = if strategy == Strategy::FullFt { 0 } else { rank };
        AdapterSpec {
            strategy,
            rank,
            alpha: rank as f32,
            niter: default_niter(strategy),
            iters: DEFAULT_ITERS,
            window: Window::Principal,
            targets: Vec::new(),
        }
    }

    pub fn full_ft() -> AdapterSpec {
        AdapterSpec::new(Strategy::FullFt, 0)
    }
    pub fn lora(rank: usize) -> AdapterSpec {
        AdapterSpec::new(Strategy::Lora, rank)
    }
    pub fn pissa(rank: usize) -> AdapterSpec {
        AdapterSpec::new(Strategy::Pissa, rank)
    }
    pub fn qlora(rank: usize) -> AdapterSpec {
        AdapterSpec::new(Strategy::QLora, rank)
    }
    pub fn qpissa(rank: usize) -> AdapterSpec {
        AdapterSpec::new(Strategy::QPissa, rank)
    }
    pub fn loftq(rank: usize) -> AdapterSpec {
        AdapterSpec::new(Strategy::LoftQ, rank)
    }

    /// Legacy bridge: the exact configuration the old
    /// `initialize(strategy, w, rank, iters, rng)` dispatch used.
    pub fn from_strategy(strategy: Strategy, rank: usize, iters: usize) -> AdapterSpec {
        let mut s = AdapterSpec::new(strategy, rank);
        s.iters = iters;
        s
    }

    // ---- chained setters -------------------------------------------------

    /// Fast SVD with `n` subspace iterations (peft's `pissa_niter_n`).
    pub fn niter(mut self, n: usize) -> AdapterSpec {
        self.niter = Some(n);
        self
    }

    /// Exact Jacobi SVD (the paper's niter = ∞).
    pub fn exact_svd(mut self) -> AdapterSpec {
        self.niter = None;
        self
    }

    /// QPiSSA/LoftQ alternation count T.
    pub fn iters(mut self, t: usize) -> AdapterSpec {
        self.iters = t;
        self
    }

    /// LoRA-style alpha (scaling = alpha / rank).
    pub fn alpha(mut self, a: f32) -> AdapterSpec {
        self.alpha = a;
        self
    }

    /// Singular-triplet window (Appendix A ablation).
    pub fn window(mut self, w: Window) -> AdapterSpec {
        self.window = w;
        self
    }

    /// Restrict the adapter to a subset of the seven linears.
    pub fn targets(mut self, modules: &[&str]) -> AdapterSpec {
        self.targets = modules
            .iter()
            .map(|m| TargetSpec { module: m.to_string(), rank: None })
            .collect();
        self
    }

    /// Per-module rank override. If no explicit target list was set, all
    /// seven linears stay targeted (the override applies on top).
    pub fn target_rank(mut self, module: &str, rank: usize) -> AdapterSpec {
        if self.targets.is_empty() {
            self.targets = LINEARS
                .iter()
                .map(|m| TargetSpec { module: m.to_string(), rank: None })
                .collect();
        }
        match self.targets.iter_mut().find(|t| t.module == module) {
            Some(t) => t.rank = Some(rank),
            None => self.targets.push(TargetSpec { module: module.to_string(), rank: Some(rank) }),
        }
        self
    }

    // ---- accessors -------------------------------------------------------

    pub fn name(&self) -> &'static str {
        self.strategy.name()
    }

    pub fn is_full_ft(&self) -> bool {
        self.strategy == Strategy::FullFt
    }

    /// Does this spec NF4-quantize its frozen base?
    pub fn quantized(&self) -> bool {
        self.strategy.quantized()
    }

    /// Is alpha at its default (== spec rank), i.e. scaling 1 everywhere?
    pub fn default_alpha(&self) -> bool {
        self.alpha == self.rank as f32
    }

    /// Effective LoRA scaling at the spec-level rank; 1.0 when unset or
    /// full-ft. Per-module-rank specs should use [`Self::module_scaling`].
    pub fn scaling(&self) -> f32 {
        self.module_scaling(self.rank)
    }

    /// Effective LoRA scaling for a module built at `module_rank`
    /// (`alpha / module_rank`, as in peft). With the default alpha the
    /// scaling is 1.0 for every module regardless of rank overrides.
    pub fn module_scaling(&self, module_rank: usize) -> f32 {
        if self.default_alpha() || module_rank == 0 {
            1.0
        } else {
            self.alpha / module_rank as f32
        }
    }

    /// Is `module` adapter-targeted under this spec? (Full-ft trains the
    /// dense weights of every linear, so it "targets" all of them.)
    pub fn targets_module(&self, module: &str) -> bool {
        self.is_full_ft()
            || self.targets.is_empty()
            || self.targets.iter().any(|t| t.module == module)
    }

    /// Rank used for `module` (spec rank unless overridden).
    pub fn module_rank(&self, module: &str) -> usize {
        self.targets
            .iter()
            .find(|t| t.module == module)
            .and_then(|t| t.rank)
            .unwrap_or(self.rank)
    }

    /// Targeted modules in canonical (`LINEARS`) order.
    pub fn target_modules(&self) -> Vec<&str> {
        LINEARS.iter().copied().filter(|m| self.targets_module(m)).collect()
    }

    /// Does the spec target all seven linears (artifact layout requirement)?
    pub fn covers_all(&self) -> bool {
        LINEARS.iter().all(|m| self.targets_module(m))
    }

    /// Are all targeted modules at the same (spec-level) rank?
    pub fn uniform_rank(&self) -> bool {
        self.targets.iter().all(|t| t.rank.is_none() || t.rank == Some(self.rank))
    }

    pub fn validate(&self) -> Result<()> {
        if self.is_full_ft() {
            anyhow::ensure!(
                self.targets.is_empty(),
                "full-ft trains the dense weights; target_modules do not apply"
            );
            return Ok(());
        }
        anyhow::ensure!(self.rank >= 1, "adapter rank must be >= 1 (got {})", self.rank);
        anyhow::ensure!(self.alpha > 0.0, "alpha must be positive (got {})", self.alpha);
        anyhow::ensure!(self.iters >= 1, "iters (Algorithm 1's T) must be >= 1");
        // Reject knobs the chosen strategy would silently ignore.
        let svd_based = matches!(
            self.strategy,
            Strategy::Pissa | Strategy::QPissa | Strategy::LoftQ
        );
        anyhow::ensure!(
            svd_based || self.niter.is_none(),
            "niter applies only to the SVD-based strategies (pissa/qpissa/loftq), \
             not {}",
            self.strategy.name()
        );
        anyhow::ensure!(
            self.window == Window::Principal || self.strategy == Strategy::Pissa,
            "window selection (Appendix A) applies only to pissa, not {}",
            self.strategy.name()
        );
        anyhow::ensure!(
            self.window == Window::Principal || self.niter.is_none(),
            "non-principal windows use exact SVD; set niter=exact alongside window={}",
            self.window.name()
        );
        for t in &self.targets {
            anyhow::ensure!(
                LINEARS.contains(&t.module.as_str()),
                "unknown target module '{}' (expected one of {:?})",
                t.module,
                LINEARS
            );
            if let Some(r) = t.rank {
                anyhow::ensure!(r >= 1, "rank override for '{}' must be >= 1", t.module);
            }
        }
        for (i, t) in self.targets.iter().enumerate() {
            anyhow::ensure!(
                !self.targets[..i].iter().any(|u| u.module == t.module),
                "duplicate target module '{}'",
                t.module
            );
        }
        Ok(())
    }

    // ---- initialization --------------------------------------------------

    /// Initialize one linear layer's adapter under this spec.
    ///
    /// For the default alpha (= rank), principal window, and the default
    /// niter this is bit-identical to the legacy
    /// `initialize(strategy, w, rank, iters, rng)` dispatch — asserted by
    /// the migration test in `rust/tests/adapter_api.rs`.
    pub fn init_matrix(&self, w: &Mat, rank: usize, rng: &mut Rng) -> AdapterInit {
        let mut out = match self.strategy {
            Strategy::FullFt => AdapterInit {
                base: Mat::zeros(w.rows, w.cols),
                a: w.clone(),
                b: Mat::eye(w.cols),
            },
            Strategy::Lora => init::lora(w, rank, rng),
            Strategy::Pissa => {
                if self.window == Window::Principal {
                    init::pissa(w, rank, self.niter, rng)
                } else {
                    init::pissa_window(w, rank, self.window)
                }
            }
            Strategy::QLora => init::qlora(w, rank, rng),
            Strategy::QPissa => init::qpissa_with(w, rank, self.iters, self.niter, rng),
            Strategy::LoftQ => init::loftq_with(w, rank, self.iters, self.niter, rng),
        };
        let s = self.module_scaling(rank);
        if s != 1.0 && self.strategy != Strategy::FullFt {
            // Fold √scaling into both factors so A·B carries the scaling
            // without a runtime knob, then recompute the residual so the
            // `base + A·B == W` (resp. quantized-base) invariant holds.
            let f = s.sqrt();
            out.a.scale(f);
            out.b.scale(f);
            match self.strategy {
                Strategy::Pissa => out.base = w.sub(&matmul(&out.a, &out.b)),
                Strategy::QPissa | Strategy::LoftQ => {
                    out.base = nf4_roundtrip(&w.sub(&matmul(&out.a, &out.b)));
                }
                // LoRA/QLoRA: B = 0 ⇒ the base is already correct.
                _ => {}
            }
        }
        out
    }

    // ---- string form -----------------------------------------------------

    /// Parse the compact string form, e.g.
    /// `pissa:rank=8:niter=4:targets=q@16,v` or `qpissa:rank=4:iters=5`.
    /// Keys: rank/r, alpha, niter (int or `exact`), iters/t, window,
    /// targets (comma list, `module[@rank]`). Unset keys take the same
    /// defaults as the builder.
    pub fn parse(s: &str) -> Result<AdapterSpec> {
        let mut parts = s.split(':');
        let head = parts.next().unwrap_or("").trim();
        let strategy = Strategy::parse(head)?;
        let mut spec = AdapterSpec::new(strategy, 4);
        let mut explicit_alpha: Option<f32> = None;
        for part in parts {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (k, v) = part
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("bad spec fragment '{part}' (want key=value)"))?;
            match k.trim() {
                "rank" | "r" => spec.rank = v.trim().parse()?,
                "alpha" => explicit_alpha = Some(v.trim().parse()?),
                "niter" => {
                    spec.niter = match v.trim() {
                        "exact" | "inf" | "none" => None,
                        n => Some(n.parse()?),
                    }
                }
                "iters" | "t" => spec.iters = v.trim().parse()?,
                "window" => spec.window = Window::parse(v.trim())?,
                "targets" => {
                    spec.targets = v
                        .split(',')
                        .map(|t| t.trim())
                        .filter(|t| !t.is_empty())
                        .map(parse_target)
                        .collect::<Result<Vec<_>>>()?;
                }
                other => anyhow::bail!("unknown AdapterSpec key '{other}'"),
            }
        }
        if strategy == Strategy::FullFt {
            spec.rank = 0;
        }
        spec.alpha = explicit_alpha.unwrap_or(spec.rank as f32);
        spec.validate()?;
        Ok(spec)
    }
}

fn parse_target(s: &str) -> Result<TargetSpec> {
    match s.split_once('@') {
        Some((m, r)) => Ok(TargetSpec {
            module: m.trim().to_string(),
            rank: Some(r.trim().parse()?),
        }),
        None => Ok(TargetSpec { module: s.to_string(), rank: None }),
    }
}

impl fmt::Display for AdapterSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:rank={}", self.strategy.name(), self.rank)?;
        if self.alpha != self.rank as f32 {
            write!(f, ":alpha={}", self.alpha)?;
        }
        if self.niter != default_niter(self.strategy) {
            match self.niter {
                Some(n) => write!(f, ":niter={n}")?,
                None => write!(f, ":niter=exact")?,
            }
        }
        if self.iters != DEFAULT_ITERS {
            write!(f, ":iters={}", self.iters)?;
        }
        if self.window != Window::Principal {
            write!(f, ":window={}", self.window.name())?;
        }
        if !self.targets.is_empty() {
            write!(f, ":targets=")?;
            for (i, t) in self.targets.iter().enumerate() {
                if i > 0 {
                    write!(f, ",")?;
                }
                match t.rank {
                    Some(r) => write!(f, "{}@{r}", t.module)?,
                    None => write!(f, "{}", t.module)?,
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builders_set_defaults() {
        let s = AdapterSpec::pissa(8);
        assert_eq!(s.rank, 8);
        assert_eq!(s.niter, Some(DEFAULT_NITER));
        assert_eq!(s.scaling(), 1.0);
        assert!(s.covers_all() && s.uniform_rank());
        assert!(s.validate().is_ok());

        let f = AdapterSpec::full_ft();
        assert_eq!(f.rank, 0);
        assert!(f.is_full_ft() && f.validate().is_ok());
    }

    #[test]
    fn targeting_and_overrides() {
        let s = AdapterSpec::pissa(8).targets(&["q", "v"]).target_rank("q", 16);
        assert!(s.targets_module("q") && s.targets_module("v"));
        assert!(!s.targets_module("gate"));
        assert_eq!(s.module_rank("q"), 16);
        assert_eq!(s.module_rank("v"), 8);
        assert_eq!(s.target_modules(), vec!["q", "v"]);
        assert!(!s.covers_all());
        assert!(!s.uniform_rank());
        assert!(s.validate().is_ok());

        // target_rank on an unrestricted spec keeps all modules targeted
        let t = AdapterSpec::lora(4).target_rank("down", 2);
        assert!(t.covers_all());
        assert_eq!(t.module_rank("down"), 2);
        assert_eq!(t.module_rank("q"), 4);
    }

    #[test]
    fn validation_rejects_bad_specs() {
        assert!(AdapterSpec::pissa(0).validate().is_err());
        assert!(AdapterSpec::pissa(4).targets(&["bogus"]).validate().is_err());
        assert!(AdapterSpec::pissa(4).targets(&["q", "q"]).validate().is_err());
        assert!(AdapterSpec::pissa(4).iters(0).validate().is_err());
        // knobs the strategy would otherwise silently ignore are rejected
        assert!(AdapterSpec::lora(4).niter(2).validate().is_err());
        assert!(AdapterSpec::qlora(4).window(Window::Minor).validate().is_err());
        assert!(AdapterSpec::pissa(4).window(Window::Minor).validate().is_err()); // needs exact_svd
        assert!(AdapterSpec::pissa(4).exact_svd().window(Window::Minor).validate().is_ok());
        assert!(AdapterSpec::qpissa(4).niter(1).validate().is_ok());
        assert!(AdapterSpec::full_ft().validate().is_ok());
    }

    #[test]
    fn display_parse_roundtrip() {
        let specs = vec![
            AdapterSpec::pissa(8),
            AdapterSpec::pissa(8).exact_svd(),
            AdapterSpec::pissa(4).exact_svd().window(Window::Minor),
            AdapterSpec::lora(4).alpha(32.0),
            AdapterSpec::qpissa(4).iters(1),
            AdapterSpec::qpissa(4).niter(16),
            AdapterSpec::loftq(2).exact_svd(),
            AdapterSpec::qlora(8).targets(&["q", "k", "v"]),
            AdapterSpec::pissa(8).targets(&["q", "v"]).target_rank("q", 16),
            AdapterSpec::full_ft(),
        ];
        for s in specs {
            let text = s.to_string();
            let back = AdapterSpec::parse(&text).unwrap();
            assert_eq!(back, s, "round-trip failed for '{text}'");
        }
    }

    #[test]
    fn niter_is_honored_by_qpissa_and_loftq() {
        let mut wgen = Rng::new(21);
        let w = Mat::randn(32, 24, 0.0, 0.3, &mut wgen);
        // legacy entry point == spec default (niter 4), bit for bit
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        let legacy = init::qpissa(&w, 4, 2, &mut r1);
        let via_spec = AdapterSpec::qpissa(4).iters(2).init_matrix(&w, 4, &mut r2);
        assert_eq!(legacy.a.data, via_spec.a.data);
        assert_eq!(legacy.base.data, via_spec.base.data);
        // a different niter produces a different initialization
        let mut r3 = Rng::new(5);
        let coarse = AdapterSpec::qpissa(4).iters(2).niter(1).init_matrix(&w, 4, &mut r3);
        assert_ne!(legacy.a.data, coarse.a.data, "qpissa must honor niter");
        let mut r4 = Rng::new(5);
        let mut r5 = Rng::new(5);
        let lq4 = AdapterSpec::loftq(4).iters(2).init_matrix(&w, 4, &mut r4);
        let lq_exact = AdapterSpec::loftq(4).iters(2).exact_svd().init_matrix(&w, 4, &mut r5);
        assert_ne!(lq4.a.data, lq_exact.a.data, "loftq must honor niter");
    }

    #[test]
    fn module_scaling_uses_the_override_rank() {
        // default alpha: scaling 1 for every module, overridden or not
        let s = AdapterSpec::lora(4).target_rank("q", 8);
        assert_eq!(s.module_scaling(s.module_rank("q")), 1.0);
        assert_eq!(s.module_scaling(s.module_rank("v")), 1.0);
        // explicit alpha: peft semantics, alpha / module_rank
        let s = AdapterSpec::lora(4).alpha(8.0).target_rank("q", 8);
        assert_eq!(s.module_scaling(s.module_rank("q")), 1.0); // 8/8
        assert_eq!(s.module_scaling(s.module_rank("v")), 2.0); // 8/4
    }

    #[test]
    fn parse_accepts_short_keys_and_rejects_junk() {
        let s = AdapterSpec::parse("pissa:r=8:t=1").unwrap();
        assert_eq!((s.rank, s.iters), (8, 1));
        assert!(AdapterSpec::parse("pissa:bogus=1").is_err());
        assert!(AdapterSpec::parse("pissa:rank").is_err());
        assert!(AdapterSpec::parse("notastrategy").is_err());
    }

    #[test]
    fn alpha_scaling_preserves_exactness() {
        let mut rng = Rng::new(11);
        let w = Mat::randn(24, 20, 0.0, 0.5, &mut rng);
        let spec = AdapterSpec::pissa(4).alpha(16.0); // scaling = 4
        assert_eq!(spec.scaling(), 4.0);
        let init = spec.init_matrix(&w, 4, &mut rng);
        let err = init.effective().sub(&w).fro() / w.fro();
        assert!(err < 1e-5, "scaled PiSSA must still preserve W (err {err})");

        // LoRA with scaling: B = 0, so exactness is trivially preserved,
        // and A is scaled by √scaling.
        let mut r1 = Rng::new(12);
        let mut r2 = Rng::new(12);
        let plain = AdapterSpec::lora(4).init_matrix(&w, 4, &mut r1);
        let scaled = AdapterSpec::lora(4).alpha(16.0).init_matrix(&w, 4, &mut r2);
        assert!((scaled.a.fro() - 2.0 * plain.a.fro()).abs() < 1e-4);
        assert_eq!(scaled.effective().sub(&w).fro(), 0.0);
    }
}
