//! `AdapterEngine` — one frozen base model, a registry of named adapters.
//!
//! The serving-path building block the flat API could not express: many
//! adapters (each initialized from its own [`AdapterSpec`], possibly
//! targeting different module subsets at different ranks) share ONE
//! frozen `BaseModel`, and requests hot-swap between them without
//! touching the base weights. Registry operations:
//!
//! * `attach` / `detach` — initialize an adapter from a spec (validating
//!   the paper's `base + A·B == W` exactness invariant per layer) or
//!   remove it,
//! * `swap` — O(1) hot-swap of the active adapter,
//! * `merge` / `unmerge` — the deployment path (§3): fold `A·B` into
//!   dense serving weights and back. The factors are never destroyed, so
//!   unmerge restores them bit-for-bit; the merged weights are a derived
//!   cache verified against the factors at unmerge time,
//! * `to_lora_delta` — the Appendix-C conversion (`ΔA = [A'|A]`,
//!   `ΔB = [B';−B]`) exported per targeted module/layer and validated
//!   against the original dense weights,
//! * `save` / `attach_saved` — v2 `PISSACKP` checkpoints that carry the
//!   spec, so a stored adapter records how it was made.

use super::convert::{pissa_to_lora, LoraDelta};
use super::init::{AdapterInit, Strategy};
use super::residency::WarmAdapter;
use super::spec::AdapterSpec;
use super::store::Checkpoint;
use crate::linalg::{matmul, Mat};
use crate::model::{BaseModel, ParamStore, Tensor, TrainState, LINEARS};
use crate::quant::nf4_roundtrip;
use crate::util::rng::Rng;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Typed registry errors for the adapter lifecycle ops. Each variant
/// carries the context a caller needs to act on it (the offending name,
/// the registered set), and maps onto an HTTP status/code pair under the
/// same convention as `ServeError::http_status`, so the wire layer can
/// return a structured 4xx instead of an opaque 500.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AdapterError {
    /// Adapter names key the registry and the wire protocol; `""` is not one.
    EmptyName,
    /// Attach / promote over an existing registration.
    AlreadyAttached { name: String },
    /// `Strategy::FullFt` offered as an adapter — the base stays frozen.
    FullFtNotAnAdapter,
    /// Detach / demote while the adapter's dense merge cache is live.
    Merged { name: String },
    /// Lookup of an unregistered name; `have` is the registered set.
    Unknown { name: String, have: Vec<String> },
    /// v1 checkpoint (or foreign file) without an embedded `AdapterSpec`.
    NoSpec { path: String },
}

impl std::fmt::Display for AdapterError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdapterError::EmptyName => write!(f, "adapter name must be non-empty"),
            AdapterError::AlreadyAttached { name } => {
                write!(f, "adapter '{name}' is already attached")
            }
            AdapterError::FullFtNotAnAdapter => {
                write!(f, "full-ft is not an adapter: the engine's base stays frozen")
            }
            AdapterError::Merged { name } => {
                write!(f, "adapter '{name}' is merged; unmerge it first")
            }
            AdapterError::Unknown { name, have } => {
                write!(f, "no adapter named '{name}' (have: {have:?})")
            }
            AdapterError::NoSpec { path } => {
                write!(f, "checkpoint '{path}' carries no AdapterSpec (v1 file?)")
            }
        }
    }
}

impl std::error::Error for AdapterError {}

impl AdapterError {
    /// HTTP status for the wire layer (`ServeError::http_status` convention).
    pub fn http_status(&self) -> u16 {
        match self {
            AdapterError::Unknown { .. } => 404,
            AdapterError::AlreadyAttached { .. } | AdapterError::Merged { .. } => 409,
            AdapterError::EmptyName
            | AdapterError::FullFtNotAnAdapter
            | AdapterError::NoSpec { .. } => 422,
        }
    }

    /// Stable machine-readable code for the structured error body.
    pub fn code(&self) -> &'static str {
        match self {
            AdapterError::EmptyName => "empty_adapter_name",
            AdapterError::AlreadyAttached { .. } => "adapter_already_attached",
            AdapterError::FullFtNotAnAdapter => "full_ft_not_adapter",
            AdapterError::Merged { .. } => "adapter_merged",
            AdapterError::Unknown { .. } => "unknown_adapter",
            AdapterError::NoSpec { .. } => "checkpoint_missing_spec",
        }
    }
}

/// Relative tolerance for the `base + A·B == W` exactness invariant
/// (full-precision strategies; quantized bases are bounded by the QLoRA
/// round-trip error instead).
pub const EXACTNESS_TOL: f64 = 1e-5;

/// Relative tolerance for fp-roundtrip checks (merge/unmerge, Appendix C).
const ROUNDTRIP_TOL: f64 = 1e-4;

/// One registered adapter: its spec, frozen residual/base stacks, current
/// factors, and the attach-time factor snapshot (Appendix C needs the
/// initial factors).
#[derive(Clone, Debug)]
pub struct NamedAdapter {
    pub spec: AdapterSpec,
    /// `base_<module>` stacks ([L, m, n]) for targeted modules.
    pub frozen: ParamStore,
    /// Current `a_<module>` / `b_<module>` factor stacks (training updates
    /// these via `set_factors`).
    pub factors: ParamStore,
    /// Factors as initialized (frozen snapshot for the Appendix-C export).
    pub init_factors: ParamStore,
}

/// Multi-adapter registry over one frozen base model.
#[derive(Debug)]
pub struct AdapterEngine {
    base: BaseModel,
    adapters: BTreeMap<String, NamedAdapter>,
    active: Option<String>,
    /// Merged dense-weight cache: at most one adapter is merged at a time.
    merged: Option<(String, ParamStore)>,
}

impl AdapterEngine {
    /// Take ownership of a (frozen) base model.
    pub fn new(base: BaseModel) -> AdapterEngine {
        AdapterEngine { base, adapters: BTreeMap::new(), active: None, merged: None }
    }

    pub fn base(&self) -> &BaseModel {
        &self.base
    }

    /// Registered adapter names (sorted).
    pub fn names(&self) -> Vec<&str> {
        self.adapters.keys().map(|s| s.as_str()).collect()
    }

    pub fn active(&self) -> Option<&str> {
        self.active.as_deref()
    }

    /// Name of the currently merged adapter, if any.
    pub fn merged(&self) -> Option<&str> {
        self.merged.as_ref().map(|(n, _)| n.as_str())
    }

    pub fn get(&self, name: &str) -> Result<&NamedAdapter> {
        self.adapters.get(name).ok_or_else(|| self.unknown(name).into())
    }

    /// The typed not-found error, with the registered set as context.
    fn unknown(&self, name: &str) -> AdapterError {
        AdapterError::Unknown {
            name: name.to_string(),
            have: self.adapters.keys().cloned().collect(),
        }
    }

    /// Original dense weight of `module` at `layer` in the frozen base.
    pub fn base_weight(&self, module: &str, layer: usize) -> Mat {
        self.base.linears[&format!("base_{module}")].layer(layer)
    }

    /// (rows, cols) of a module's base weight — the same for every layer
    /// of the stack — read off the stacked tensor's shape without copying
    /// a matrix out (validation walks all `L × 7` linears).
    pub fn base_dims(&self, module: &str) -> (usize, usize) {
        let t = &self.base.linears[&format!("base_{module}")];
        (t.shape[1], t.shape[2])
    }

    /// Blockwise-NF4 snapshot of the base weight — what the
    /// quantized-base serving strategies keep resident instead of the
    /// dense matrix (§4's QPiSSA deployment trade: ~0.14× the bytes, at
    /// the NF4 round-trip error the paper bounds in Table 3).
    pub fn quant_base_weight(&self, module: &str, layer: usize) -> crate::quant::Nf4Tensor {
        crate::quant::quantize(&self.base_weight(module, layer))
    }

    /// One shared NF4 snapshot of a module's whole stacked base weight:
    /// every layer quantized once, handed out as `Arc` clones. The
    /// full-model serving pipeline builds one stack per module and gives
    /// each of its L per-layer serving units a handle, so the module's
    /// NF4 codes are resident exactly once no matter how many layers (or
    /// rebuilt servers) stream from them.
    pub fn quant_base_stack(&self, module: &str) -> crate::quant::Nf4Stack {
        let mats: Vec<Mat> =
            (0..self.base.n_layers()).map(|li| self.base_weight(module, li)).collect();
        crate::quant::Nf4Stack::quantize_layers(&mats)
    }

    /// Low-rank SERVING delta of one adapter for `(module, layer)`,
    /// against the ORIGINAL dense weight `W`: `None` when the adapter
    /// does not target the module (serve the base unchanged); the current
    /// factors themselves (rank r) when the frozen residual is `W` (the
    /// LoRA-style zero-B init); otherwise the Appendix-C equivalent-LoRA
    /// pair `ΔA = [A'|A₀], ΔB = [B';−B₀]` at rank 2r, which plugs into
    /// `W` exactly for full-precision adapters and to the NF4 round-trip
    /// error (the paper's Table-3 bound) for quantized ones.
    pub fn serve_delta(
        &self,
        name: &str,
        module: &str,
        layer: usize,
    ) -> Result<Option<(Mat, Mat)>> {
        let ad = self.get(name)?;
        if !ad.spec.targets_module(module) {
            return Ok(None);
        }
        let a0 = ad.init_factors[&format!("a_{module}")].layer(layer);
        let b0 = ad.init_factors[&format!("b_{module}")].layer(layer);
        let a1 = ad.factors[&format!("a_{module}")].layer(layer);
        let b1 = ad.factors[&format!("b_{module}")].layer(layer);
        if b0.data.iter().all(|&x| x == 0.0) {
            // Frozen residual is W itself: the factors ARE the delta.
            Ok(Some((a1, b1)))
        } else {
            let d = pissa_to_lora(&a0, &b0, &a1, &b1);
            Ok(Some((d.da, d.db)))
        }
    }

    /// Initialize and register an adapter from a spec. The first attached
    /// adapter becomes active. Every layer's init is validated against
    /// the exactness invariant before the adapter is accepted.
    pub fn attach(&mut self, name: &str, spec: AdapterSpec, rng: &mut Rng) -> Result<()> {
        anyhow::ensure!(!name.is_empty(), AdapterError::EmptyName);
        anyhow::ensure!(
            !self.adapters.contains_key(name),
            AdapterError::AlreadyAttached { name: name.to_string() }
        );
        anyhow::ensure!(spec.strategy != Strategy::FullFt, AdapterError::FullFtNotAnAdapter);
        spec.validate()?;
        let l = self.base.n_layers();
        let mut frozen = ParamStore::new();
        let mut factors = ParamStore::new();
        for module in LINEARS {
            if !spec.targets_module(module) {
                continue;
            }
            let stacked = &self.base.linears[&format!("base_{module}")];
            let rank = spec.module_rank(module);
            let mut bases = Vec::with_capacity(l);
            let mut aas = Vec::with_capacity(l);
            let mut bbs = Vec::with_capacity(l);
            for li in 0..l {
                let w = stacked.layer(li);
                let init = spec.init_matrix(&w, rank, rng);
                check_exactness(&spec, &w, &init)
                    .with_context(|| format!("adapter '{name}': {module}[{li}]"))?;
                bases.push(init.base);
                aas.push(init.a);
                bbs.push(init.b);
            }
            frozen.insert(format!("base_{module}"), Tensor::stack(&bases));
            factors.insert(format!("a_{module}"), Tensor::stack(&aas));
            factors.insert(format!("b_{module}"), Tensor::stack(&bbs));
        }
        let init_factors = factors.clone();
        self.adapters
            .insert(name.to_string(), NamedAdapter { spec, frozen, factors, init_factors });
        if self.active.is_none() {
            self.active = Some(name.to_string());
        }
        Ok(())
    }

    /// Remove an adapter from the registry (must not be merged).
    pub fn detach(&mut self, name: &str) -> Result<NamedAdapter> {
        if let Some((m, _)) = &self.merged {
            anyhow::ensure!(m != name, AdapterError::Merged { name: name.to_string() });
        }
        anyhow::ensure!(self.adapters.contains_key(name), self.unknown(name));
        let ad = self.adapters.remove(name).expect("checked above");
        if self.active.as_deref() == Some(name) {
            self.active = None;
        }
        Ok(ad)
    }

    /// Hot-swap the active adapter. O(1): only the registry pointer moves;
    /// the frozen base is untouched. Returns the previously active name.
    pub fn swap(&mut self, name: &str) -> Result<Option<String>> {
        anyhow::ensure!(self.adapters.contains_key(name), self.unknown(name));
        Ok(self.active.replace(name.to_string()))
    }

    /// Effective serving weight of `module` at `layer` under the ACTIVE
    /// adapter: `base + A·B` for targeted modules (the merged dense cache
    /// when merged), the original dense weight otherwise.
    pub fn effective_weight(&self, module: &str, layer: usize) -> Result<Mat> {
        let name = self
            .active
            .clone()
            .ok_or_else(|| anyhow::anyhow!("no active adapter (attach/swap one first)"))?;
        self.effective_weight_of(&name, module, layer)
    }

    /// Effective serving weight under a specific adapter.
    pub fn effective_weight_of(&self, name: &str, module: &str, layer: usize) -> Result<Mat> {
        let ad = self.get(name)?;
        if !ad.spec.targets_module(module) {
            return Ok(self.base_weight(module, layer));
        }
        if let Some((m, dense)) = &self.merged {
            if m == name {
                return Ok(dense[&format!("base_{module}")].layer(layer));
            }
        }
        let base = ad.frozen[&format!("base_{module}")].layer(layer);
        let a = ad.factors[&format!("a_{module}")].layer(layer);
        let b = ad.factors[&format!("b_{module}")].layer(layer);
        Ok(base.add(&matmul(&a, &b)))
    }

    /// Deployment path (§3): fold `A·B` into dense serving weights for
    /// every targeted module. The factors are retained, so this is fully
    /// reversible; at most one adapter may be merged at a time.
    pub fn merge(&mut self, name: &str) -> Result<()> {
        if let Some((m, _)) = &self.merged {
            anyhow::bail!("adapter '{m}' is already merged; unmerge it first");
        }
        let ad = self.get(name)?;
        let l = self.base.n_layers();
        let mut dense = ParamStore::new();
        for module in LINEARS {
            if !ad.spec.targets_module(module) {
                continue;
            }
            let mut merged_layers = Vec::with_capacity(l);
            for li in 0..l {
                let base = ad.frozen[&format!("base_{module}")].layer(li);
                let a = ad.factors[&format!("a_{module}")].layer(li);
                let b = ad.factors[&format!("b_{module}")].layer(li);
                merged_layers.push(base.add(&matmul(&a, &b)));
            }
            dense.insert(format!("base_{module}"), Tensor::stack(&merged_layers));
        }
        self.merged = Some((name.to_string(), dense));
        Ok(())
    }

    /// Undo a merge. Runtime invariant: subtracting `A·B` from the merged
    /// dense weights must reproduce the frozen base (to fp tolerance);
    /// the factors themselves were never touched, so they are restored
    /// exactly.
    pub fn unmerge(&mut self, name: &str) -> Result<()> {
        let dense = match &self.merged {
            Some((m, dense)) if m == name => dense,
            Some((m, _)) => anyhow::bail!("adapter '{m}' is merged, not '{name}'"),
            None => anyhow::bail!("no adapter is merged"),
        };
        let ad = self.get(name)?;
        let l = self.base.n_layers();
        for module in LINEARS {
            if !ad.spec.targets_module(module) {
                continue;
            }
            for li in 0..l {
                let merged = dense[&format!("base_{module}")].layer(li);
                let a = ad.factors[&format!("a_{module}")].layer(li);
                let b = ad.factors[&format!("b_{module}")].layer(li);
                let back = merged.sub(&matmul(&a, &b));
                let frozen = ad.frozen[&format!("base_{module}")].layer(li);
                let err = back.sub(&frozen).fro() / frozen.fro().max(1e-30);
                anyhow::ensure!(
                    err < ROUNDTRIP_TOL,
                    "unmerge('{name}') {module}[{li}]: merged − A·B deviates from the \
                     frozen base (rel err {err:.3e}) — factors changed while merged?"
                );
            }
        }
        self.merged = None;
        Ok(())
    }

    /// Replace one layer's factors (e.g. after a training run). Rejected
    /// while the adapter is merged: the dense cache would go stale.
    pub fn set_factors(
        &mut self,
        name: &str,
        module: &str,
        layer: usize,
        a: &Mat,
        b: &Mat,
    ) -> Result<()> {
        if let Some((m, _)) = &self.merged {
            anyhow::ensure!(
                m != name,
                "adapter '{name}' is merged; unmerge before updating factors"
            );
        }
        let ad = self
            .adapters
            .get_mut(name)
            .ok_or_else(|| anyhow::anyhow!("no adapter named '{name}'"))?;
        anyhow::ensure!(
            ad.spec.targets_module(module),
            "adapter '{name}' does not target module '{module}'"
        );
        let at = ad
            .factors
            .get_mut(&format!("a_{module}"))
            .ok_or_else(|| anyhow::anyhow!("missing a_{module}"))?;
        anyhow::ensure!(
            at.shape[1] == a.rows && at.shape[2] == a.cols,
            "a_{module}[{layer}]: got {}x{}, want {}x{}",
            a.rows,
            a.cols,
            at.shape[1],
            at.shape[2]
        );
        at.set_layer(layer, a);
        let bt = ad
            .factors
            .get_mut(&format!("b_{module}"))
            .ok_or_else(|| anyhow::anyhow!("missing b_{module}"))?;
        anyhow::ensure!(
            bt.shape[1] == b.rows && bt.shape[2] == b.cols,
            "b_{module}[{layer}]: got {}x{}, want {}x{}",
            b.rows,
            b.cols,
            bt.shape[1],
            bt.shape[2]
        );
        bt.set_layer(layer, b);
        Ok(())
    }

    /// Appendix-C export: per targeted module, the per-layer equivalent
    /// LoRA deltas `ΔA = [A'|A], ΔB = [B';−B]` that plug into the
    /// ORIGINAL dense weights. Each delta is validated at runtime:
    /// `W_orig + ΔA·ΔB == base + A'·B'`. Quantized strategies are
    /// rejected — their frozen base is not the full-precision residual,
    /// so the identity does not hold against the original W.
    pub fn to_lora_delta(&self, name: &str) -> Result<BTreeMap<String, Vec<LoraDelta>>> {
        let ad = self.get(name)?;
        anyhow::ensure!(
            !ad.spec.quantized(),
            "Appendix-C conversion needs a full-precision residual; strategy '{}' \
             quantizes its frozen base",
            ad.spec.name()
        );
        let l = self.base.n_layers();
        let mut out = BTreeMap::new();
        for module in LINEARS {
            if !ad.spec.targets_module(module) {
                continue;
            }
            let mut deltas = Vec::with_capacity(l);
            for li in 0..l {
                let a0 = ad.init_factors[&format!("a_{module}")].layer(li);
                let b0 = ad.init_factors[&format!("b_{module}")].layer(li);
                let a1 = ad.factors[&format!("a_{module}")].layer(li);
                let b1 = ad.factors[&format!("b_{module}")].layer(li);
                let delta = pissa_to_lora(&a0, &b0, &a1, &b1);
                // Invariant (Eq. 9–10): applying the delta to the original
                // W reproduces the adapter's effective weight.
                let via = self.base_weight(module, li).add(&delta.delta());
                let direct =
                    ad.frozen[&format!("base_{module}")].layer(li).add(&matmul(&a1, &b1));
                let err = via.sub(&direct).fro() / direct.fro().max(1e-30);
                anyhow::ensure!(
                    err < ROUNDTRIP_TOL,
                    "to_lora_delta('{name}') {module}[{li}]: conversion rel err {err:.3e}"
                );
                deltas.push(delta);
            }
            out.insert(module.to_string(), deltas);
        }
        Ok(out)
    }

    /// Bridge an adapter into the artifact-driven `Trainer`. The AOT
    /// artifact layout requires all seven linears at one rank, so partial
    /// or per-module-rank specs are rejected with a clear error.
    pub fn state(&self, name: &str) -> Result<TrainState> {
        let ad = self.get(name)?;
        anyhow::ensure!(
            ad.spec.covers_all() && ad.spec.uniform_rank(),
            "train artifacts are lowered for adapters on all seven linears at one \
             rank; spec '{}' targets [{}] — partial targeting is served by the \
             engine directly",
            ad.spec,
            ad.spec.target_modules().join(",")
        );
        let mut frozen = self.base.scaffold.clone();
        let mut trainable = ParamStore::new();
        if self.base.encoder {
            let cls = &self.base.scaffold["cls_base"];
            trainable.insert("cls_head".into(), Tensor::zeros(&cls.shape));
        }
        for (k, t) in &ad.frozen {
            frozen.insert(k.clone(), t.clone());
        }
        for (k, t) in &ad.factors {
            trainable.insert(k.clone(), t.clone());
        }
        Ok(TrainState::new(ad.spec.clone(), frozen, trainable))
    }

    /// Persist one adapter (spec + frozen + current factors + init
    /// snapshot) as a v2 `PISSACKP` checkpoint.
    pub fn save(&self, name: &str, path: &Path) -> Result<()> {
        let ad = self.get(name)?;
        let mut ckp = Checkpoint::new();
        ckp.spec = Some(ad.spec.clone());
        for (k, t) in &ad.frozen {
            ckp.put_tensor(&format!("frozen.{k}"), t);
        }
        for (k, t) in &ad.factors {
            ckp.put_tensor(&format!("factors.{k}"), t);
        }
        for (k, t) in &ad.init_factors {
            ckp.put_tensor(&format!("init.{k}"), t);
        }
        ckp.save(path)
    }

    /// Register an adapter previously stored with [`AdapterEngine::save`].
    pub fn attach_saved(&mut self, name: &str, path: &Path) -> Result<()> {
        anyhow::ensure!(
            !self.adapters.contains_key(name),
            AdapterError::AlreadyAttached { name: name.to_string() }
        );
        let ckp = Checkpoint::load(path)?;
        let spec = ckp
            .spec
            .clone()
            .ok_or(AdapterError::NoSpec { path: path.display().to_string() })?;
        spec.validate()?;
        let mut frozen = ParamStore::new();
        let mut factors = ParamStore::new();
        let mut init_factors = ParamStore::new();
        let l = self.base.n_layers();
        for module in LINEARS {
            if !spec.targets_module(module) {
                continue;
            }
            let base_t = ckp.get_tensor(&format!("frozen.base_{module}"))?;
            let expect = &self.base.linears[&format!("base_{module}")].shape;
            anyhow::ensure!(
                &base_t.shape == expect,
                "saved adapter '{name}' base_{module} shape {:?} vs base model {:?}",
                base_t.shape,
                expect
            );
            anyhow::ensure!(base_t.shape[0] == l, "layer count mismatch for {module}");
            let a0_t = ckp.get_tensor(&format!("init.a_{module}"))?;
            let b0_t = ckp.get_tensor(&format!("init.b_{module}"))?;
            // The attach-time invariant must hold against THIS engine's
            // base: frozen + A₀·B₀ == W (resp. the quantized bound).
            // Catches adapters saved against a different base model,
            // which match on shape but serve an inconsistent mix.
            for li in 0..l {
                let w = self.base_weight(module, li);
                let probe = AdapterInit {
                    base: base_t.layer(li),
                    a: a0_t.layer(li),
                    b: b0_t.layer(li),
                };
                check_exactness(&spec, &w, &probe).with_context(|| {
                    format!(
                        "attach_saved('{name}') {module}[{li}]: saved adapter does not \
                         decompose this engine's base (wrong base model?)"
                    )
                })?;
            }
            frozen.insert(format!("base_{module}"), base_t);
            factors.insert(format!("a_{module}"), ckp.get_tensor(&format!("factors.a_{module}"))?);
            factors.insert(format!("b_{module}"), ckp.get_tensor(&format!("factors.b_{module}"))?);
            init_factors.insert(format!("a_{module}"), a0_t);
            init_factors.insert(format!("b_{module}"), b0_t);
        }
        self.adapters
            .insert(name.to_string(), NamedAdapter { spec, frozen, factors, init_factors });
        if self.active.is_none() {
            self.active = Some(name.to_string());
        }
        Ok(())
    }

    /// Cold-tier attach-on-miss: register an adapter from its on-disk
    /// `PISSACKP` on first request. Identical to
    /// [`AdapterEngine::attach_saved`] — the full shape + exactness
    /// validation runs against THIS base, so a cold reload of a
    /// full-precision adapter restores the exact tensors that were
    /// spilled (the eviction-invariance contract) — spelled as its own
    /// lifecycle op because the residency layer treats it as one.
    pub fn attach_cold(&mut self, name: &str, path: &Path) -> Result<()> {
        self.attach_saved(name, path)
    }

    /// Demote an adapter out of the hot tier: write a lossless f32
    /// spill checkpoint (so a later promotion — or a cold reload — can
    /// restore the exact bytes), detach it from the registry, and return
    /// the blockwise-NF4 warm copy (~0.14× the f32 bytes). The spill is
    /// written BEFORE the registry shrinks, so a failed demote leaves
    /// the engine unchanged.
    pub fn demote(&mut self, name: &str, spill: &Path) -> Result<WarmAdapter> {
        if let Some((m, _)) = &self.merged {
            anyhow::ensure!(m != name, AdapterError::Merged { name: name.to_string() });
        }
        anyhow::ensure!(self.adapters.contains_key(name), self.unknown(name));
        self.save(name, spill)?;
        let ad = self.detach(name)?;
        WarmAdapter::from_named(name, &ad)
    }

    /// Promote a warm NF4 copy back into the registry. The restore is a
    /// deterministic dequantization, so two promotions of the same warm
    /// copy are bit-identical — but it is NOT the attach-time exactness
    /// invariant: the NF4 round trip moved the tensors off the exact
    /// decomposition by design (bounded by
    /// [`super::residency::WARM_NF4_REL_TOL`], asserted when the warm
    /// copy was made). Shapes are still validated against THIS base.
    pub fn promote(&mut self, warm: &WarmAdapter) -> Result<()> {
        let name = warm.name();
        anyhow::ensure!(
            !self.adapters.contains_key(name),
            AdapterError::AlreadyAttached { name: name.to_string() }
        );
        let ad = warm.to_named();
        for module in LINEARS {
            if !ad.spec.targets_module(module) {
                continue;
            }
            let expect = &self.base.linears[&format!("base_{module}")].shape;
            let got = &ad.frozen[&format!("base_{module}")].shape;
            anyhow::ensure!(
                got == expect,
                "warm adapter '{name}' base_{module} shape {got:?} vs base model {expect:?}"
            );
        }
        self.adapters.insert(name.to_string(), ad);
        if self.active.is_none() {
            self.active = Some(name.to_string());
        }
        Ok(())
    }

    /// Resident f32 bytes of one adapter's engine-side tensors (frozen
    /// residual + current factors + init snapshot) — the hot tier's
    /// engine share of the `adapter_budget_bytes` accounting.
    pub fn adapter_bytes(&self, name: &str) -> Result<usize> {
        let ad = self.get(name)?;
        let store = |s: &ParamStore| -> usize { s.values().map(|t| t.data.len() * 4).sum() };
        Ok(store(&ad.frozen) + store(&ad.factors) + store(&ad.init_factors))
    }
}

/// The paper's exactness invariant, checked at attach time.
/// Full-precision strategies must preserve W to [`EXACTNESS_TOL`].
/// Quantized strategies can't preserve W exactly; their structural
/// invariant is that the frozen base is an NF4 fixed point, and — at
/// standard scaling — the effective error must not exceed the plain
/// NF4(W) round-trip (QLoRA) error by more than 5% (the paper's Table 3
/// claim; alpha-scaled factors inflate the residual, so the bound is
/// only asserted when scaling == 1).
fn check_exactness(spec: &AdapterSpec, w: &Mat, init: &AdapterInit) -> Result<()> {
    let err = init.effective().sub(w).fro();
    if spec.quantized() {
        let refix = init.base.sub(&nf4_roundtrip(&init.base)).fro();
        anyhow::ensure!(
            refix < 1e-5 * (1.0 + init.base.fro()),
            "quantized base is not an NF4 fixed point (re-quantization moves it by {refix:.3e})"
        );
        if spec.default_alpha() {
            // 10% slack covers near-flat spectra (random-init weights),
            // where the principal-component reduction is marginal.
            let bound = w.sub(&nf4_roundtrip(w)).fro() * 1.10 + 1e-9;
            anyhow::ensure!(
                err <= bound,
                "quantized init error {err:.3e} exceeds the QLoRA bound {bound:.3e}"
            );
        }
    } else {
        let rel = err / w.fro().max(1e-30);
        anyhow::ensure!(
            rel < EXACTNESS_TOL,
            "base + A·B deviates from W: rel err {rel:.3e}"
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ConfigInfo;

    fn tiny_cfg() -> ConfigInfo {
        ConfigInfo {
            name: "engine-test".into(),
            kind: "decoder".into(),
            vocab: 128,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 64,
            seq_len: 32,
            batch: 4,
            eval_batch: 2,
            n_classes: 0,
            ranks: vec![2, 4],
        }
    }

    fn engine(seed: u64) -> (AdapterEngine, Rng) {
        let mut rng = Rng::new(seed);
        let base = BaseModel::random(&tiny_cfg(), &mut rng);
        (AdapterEngine::new(base), rng)
    }

    #[test]
    fn attach_swap_detach_lifecycle() {
        let (mut eng, mut rng) = engine(1);
        eng.attach("p", AdapterSpec::pissa(4).targets(&["q", "v"]), &mut rng).unwrap();
        eng.attach("l", AdapterSpec::lora(2), &mut rng).unwrap();
        assert_eq!(eng.active(), Some("p")); // first attach activates
        assert_eq!(eng.names(), vec!["l", "p"]);
        assert!(eng.attach("p", AdapterSpec::lora(2), &mut rng).is_err()); // dup
        let prev = eng.swap("l").unwrap();
        assert_eq!(prev.as_deref(), Some("p"));
        assert_eq!(eng.active(), Some("l"));
        let det = eng.detach("l").unwrap();
        assert_eq!(det.spec.strategy, Strategy::Lora);
        assert_eq!(eng.active(), None);
        assert!(eng.swap("l").is_err());
    }

    #[test]
    fn untargeted_modules_serve_the_base_weight() {
        let (mut eng, mut rng) = engine(2);
        eng.attach("p", AdapterSpec::pissa(4).targets(&["q"]), &mut rng).unwrap();
        let w_gate = eng.effective_weight("gate", 0).unwrap();
        assert_eq!(w_gate.data, eng.base_weight("gate", 0).data);
        // Targeted module preserves W too (exactness), but via base + A·B.
        let w_q = eng.effective_weight("q", 0).unwrap();
        let orig = eng.base_weight("q", 0);
        assert!(w_q.sub(&orig).fro() / orig.fro() < 1e-5);
    }

    #[test]
    fn merge_unmerge_roundtrip_and_guards() {
        let (mut eng, mut rng) = engine(3);
        eng.attach("p", AdapterSpec::pissa(4), &mut rng).unwrap();
        eng.attach("l", AdapterSpec::lora(2), &mut rng).unwrap();
        let factors_before = eng.get("p").unwrap().factors.clone();
        let eff_before = eng.effective_weight_of("p", "q", 1).unwrap();
        eng.merge("p").unwrap();
        assert_eq!(eng.merged(), Some("p"));
        // merged serving weight is the same effective weight
        let eff_merged = eng.effective_weight_of("p", "q", 1).unwrap();
        assert_eq!(eff_merged.data, eff_before.data);
        // guards: second merge, detach-while-merged, set_factors-while-merged
        assert!(eng.merge("l").is_err());
        assert!(eng.detach("p").is_err());
        let a = factors_before["a_q"].layer(0);
        let b = factors_before["b_q"].layer(0);
        assert!(eng.set_factors("p", "q", 0, &a, &b).is_err());
        eng.unmerge("p").unwrap();
        assert_eq!(eng.merged(), None);
        // factors restored bit-for-bit
        for (k, t) in &factors_before {
            assert_eq!(t.data, eng.get("p").unwrap().factors[k].data, "factor {k} changed");
        }
    }

    #[test]
    fn full_ft_is_not_an_adapter() {
        let (mut eng, mut rng) = engine(4);
        assert!(eng.attach("f", AdapterSpec::full_ft(), &mut rng).is_err());
    }

    #[test]
    fn lora_delta_export_validates() {
        let (mut eng, mut rng) = engine(5);
        eng.attach("p", AdapterSpec::pissa(3).targets(&["q", "v"]), &mut rng).unwrap();
        // simulate training drift, then export
        let (a1, b1) = {
            let ad = eng.get("p").unwrap();
            let mut a = ad.factors["a_q"].layer(0);
            let mut b = ad.factors["b_q"].layer(0);
            for x in a.data.iter_mut() {
                *x += 0.05 * rng.normal_f32(0.0, 1.0);
            }
            for x in b.data.iter_mut() {
                *x += 0.05 * rng.normal_f32(0.0, 1.0);
            }
            (a, b)
        };
        eng.set_factors("p", "q", 0, &a1, &b1).unwrap();
        let deltas = eng.to_lora_delta("p").unwrap();
        assert_eq!(deltas.len(), 2);
        assert_eq!(deltas["q"].len(), 2);
        // ΔA is m×2r
        assert_eq!(deltas["q"][0].da.cols, 6);
        // quantized adapters refuse the export
        eng.attach("qp", AdapterSpec::qpissa(2).iters(1), &mut rng).unwrap();
        assert!(eng.to_lora_delta("qp").is_err());
    }

    #[test]
    fn state_bridge_requires_full_uniform_targeting() {
        let (mut eng, mut rng) = engine(6);
        eng.attach("partial", AdapterSpec::pissa(2).targets(&["q"]), &mut rng).unwrap();
        assert!(eng.state("partial").is_err());
        eng.attach("fullcov", AdapterSpec::pissa(2), &mut rng).unwrap();
        let st = eng.state("fullcov").unwrap();
        assert_eq!(st.rank(), 2);
        assert!(st.trainable.contains_key("a_down"));
        assert!(st.frozen.contains_key("base_down"));
        assert!(st.frozen.contains_key("embed"));
    }

    #[test]
    fn serve_delta_plugs_into_the_original_weight() {
        let (mut eng, mut rng) = engine(8);
        eng.attach("p", AdapterSpec::pissa(3).targets(&["q"]), &mut rng).unwrap();
        // Untargeted module: no delta.
        assert!(eng.serve_delta("p", "v", 0).unwrap().is_none());
        // Drift, then check W + ΔA·ΔB == effective weight (Appendix C).
        let (mut a, mut b) = {
            let ad = eng.get("p").unwrap();
            (ad.factors["a_q"].layer(0), ad.factors["b_q"].layer(0))
        };
        for x in a.data.iter_mut().chain(b.data.iter_mut()) {
            *x += 0.05 * rng.normal_f32(0.0, 1.0);
        }
        eng.set_factors("p", "q", 0, &a, &b).unwrap();
        let (da, db) = eng.serve_delta("p", "q", 0).unwrap().unwrap();
        assert_eq!(da.cols, 6, "PiSSA serve delta is the rank-2r Appendix-C pair");
        let via = eng.base_weight("q", 0).add(&matmul(&da, &db));
        let want = eng.effective_weight_of("p", "q", 0).unwrap();
        assert!(via.sub(&want).fro() / want.fro() < 1e-4);
        // LoRA (zero-B init): the delta is the raw rank-r factors.
        eng.attach("l", AdapterSpec::lora(3).targets(&["q"]), &mut rng).unwrap();
        let (la, _) = eng.serve_delta("l", "q", 0).unwrap().unwrap();
        assert_eq!(la.cols, 3);
    }

    #[test]
    fn quant_base_stack_matches_per_layer_snapshots() {
        let (eng, _) = engine(9);
        assert_eq!(eng.base_dims("gate"), (32, 64));
        assert_eq!(eng.base_dims("down"), (64, 32));
        let stack = eng.quant_base_stack("gate");
        assert_eq!(stack.n_layers(), 2);
        let mut total = 0;
        for li in 0..2 {
            let solo = eng.quant_base_weight("gate", li);
            let shared = stack.layer(li);
            assert_eq!(shared.codes, solo.codes);
            assert_eq!(shared.scales, solo.scales);
            total += shared.storage_bytes();
        }
        assert_eq!(stack.storage_bytes(), total);
    }

    #[test]
    fn save_and_attach_saved_roundtrip() {
        let (mut eng, mut rng) = engine(7);
        eng.attach("p", AdapterSpec::pissa(3).targets(&["q", "v"]).target_rank("q", 4), &mut rng)
            .unwrap();
        let dir = std::env::temp_dir().join("pissa_engine_save_test");
        let path = dir.join("p.ckpt");
        eng.save("p", &path).unwrap();

        // reload into a second engine over the same base
        let mut eng2 = AdapterEngine::new(eng.base().clone());
        eng2.attach_saved("p", &path).unwrap();
        let (a, b) = (eng.get("p").unwrap(), eng2.get("p").unwrap());
        assert_eq!(a.spec, b.spec);
        for (k, t) in &a.factors {
            assert_eq!(t.data, b.factors[k].data);
            assert_eq!(t.shape, b.factors[k].shape);
        }
        for (k, t) in &a.frozen {
            assert_eq!(t.data, b.frozen[k].data);
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
