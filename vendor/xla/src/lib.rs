//! Local API-compatible stand-in for the PJRT-backed `xla` crate.
//!
//! The offline build environment does not ship the real `xla` crate (which
//! links the PJRT C API). This crate exposes the exact API surface the
//! coordinator uses so the whole workspace builds and tests run:
//!
//! * `Literal` — fully functional host-side tensors (f32/i32/tuple) with
//!   `vec1`/`scalar`/`reshape`/`to_vec`/`get_first_element`/
//!   `decompose_tuple`, matching the real crate's semantics. All literal
//!   marshalling round-trips bit-exactly.
//! * `PjRtClient`/`PjRtLoadedExecutable` — client construction succeeds
//!   (so harnesses can boot and report), but `compile` returns a clear
//!   error: executing HLO artifacts requires the real PJRT-backed crate.
//!   Every artifact-driven test already skips when `artifacts/` is absent.
//!
//! Swap the `xla` path dependency in the workspace `Cargo.toml` for the
//! real crate to run AOT artifacts; no coordinator code changes needed.

use std::fmt;

/// Error type mirroring the real crate's (string-carrying) error.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn new(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element storage for a literal.
#[derive(Debug, Clone, PartialEq)]
pub enum Elems {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Element types the coordinator marshals.
pub trait NativeType: Copy {
    fn wrap(v: &[Self]) -> Elems;
    fn unwrap(e: &Elems) -> Option<Vec<Self>>;
    fn type_name() -> &'static str;
}

impl NativeType for f32 {
    fn wrap(v: &[f32]) -> Elems {
        Elems::F32(v.to_vec())
    }
    fn unwrap(e: &Elems) -> Option<Vec<f32>> {
        match e {
            Elems::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
    fn type_name() -> &'static str {
        "f32"
    }
}

impl NativeType for i32 {
    fn wrap(v: &[i32]) -> Elems {
        Elems::I32(v.to_vec())
    }
    fn unwrap(e: &Elems) -> Option<Vec<i32>> {
        match e {
            Elems::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
    fn type_name() -> &'static str {
        "i32"
    }
}

/// Host-side tensor: dims + typed element buffer. Fully functional.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    elems: Elems,
}

impl Literal {
    /// 1-D literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], elems: T::wrap(data) }
    }

    /// Rank-0 (scalar) literal.
    pub fn scalar<T: NativeType>(x: T) -> Literal {
        Literal { dims: Vec::new(), elems: T::wrap(&[x]) }
    }

    /// Tuple literal (what executables return with return_tuple=True).
    pub fn tuple(elems: Vec<Literal>) -> Literal {
        Literal { dims: vec![elems.len() as i64], elems: Elems::Tuple(elems) }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn element_count(&self) -> usize {
        match &self.elems {
            Elems::F32(v) => v.len(),
            Elems::I32(v) => v.len(),
            Elems::Tuple(t) => t.len(),
        }
    }

    /// Reinterpret the buffer under new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() {
            return Err(Error::new(format!(
                "reshape to {dims:?} ({n} elems) from buffer of {} elems",
                self.element_count()
            )));
        }
        Ok(Literal { dims: dims.to_vec(), elems: self.elems.clone() })
    }

    /// Copy the flat element buffer out.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.elems).ok_or_else(|| {
            Error::new(format!("literal does not hold {} elements", T::type_name()))
        })
    }

    /// First element (scalar extraction).
    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        let v = self.to_vec::<T>()?;
        v.first().copied().ok_or_else(|| Error::new("empty literal"))
    }

    /// Split a tuple literal into its elements (consumes the contents).
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        match &mut self.elems {
            Elems::Tuple(t) => Ok(std::mem::take(t)),
            _ => Err(Error::new("decompose_tuple on a non-tuple literal")),
        }
    }
}

/// Parsed HLO module (stub: carries the artifact text).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::new(format!("reading HLO text {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

/// Computation wrapper (stub).
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _text: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _text: proto.text.clone() }
    }
}

/// Device-buffer handle (stub: holds a host literal).
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.lit.clone())
    }
}

/// Loaded executable (stub: cannot run).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(
            "PJRT execution unavailable: this build links the local xla stub; \
             swap vendor/xla for the real PJRT-backed crate to run artifacts",
        ))
    }
}

/// PJRT client (stub: boots, but cannot compile).
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    pub fn platform_name(&self) -> String {
        "cpu (local xla stub; PJRT execution unavailable)".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::new(
            "artifact compilation unavailable: this build links the local xla \
             stub; swap vendor/xla for the real PJRT-backed crate",
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(lit.dims(), &[2, 2]);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(lit.get_first_element::<f32>().unwrap(), 1.0);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn literal_scalar_and_tuple() {
        let s = Literal::scalar(7i32);
        assert_eq!(s.get_first_element::<i32>().unwrap(), 7);
        let mut t = Literal::tuple(vec![Literal::scalar(1.0f32), Literal::scalar(2.0f32)]);
        let parts = t.decompose_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[1].get_first_element::<f32>().unwrap(), 2.0);
    }

    #[test]
    fn reshape_checks_count() {
        assert!(Literal::vec1(&[1.0f32, 2.0]).reshape(&[3]).is_err());
    }

    #[test]
    fn client_boots_but_compile_errors() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c.platform_name().contains("cpu"));
        let comp = XlaComputation::from_proto(&HloModuleProto { text: String::new() });
        assert!(c.compile(&comp).is_err());
    }
}
