"""AOT pipeline consistency: the manifest must describe exactly the HLO
we lower, because the rust runtime marshals literals by manifest order."""

import json
import os

import jax.numpy as jnp
import pytest

from compile import aot
from compile import configs as C
from compile import model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_lower_train_entry_consistent():
    hlo, entry = aot.lower_train(C.TINY, 4, full_ft=False)
    # arg count = 4 data + frozen + 3×trainable
    expect = 4 + entry["n_frozen"] + 3 * entry["n_trainable"]
    assert len(entry["args"]) == expect
    assert entry["outputs"][0]["name"] == "loss"
    assert entry["outputs"][1]["name"] == "grad_norm"
    assert len(entry["outputs"]) == 2 + 3 * entry["n_trainable"]
    assert "ENTRY" in hlo and "HloModule" in hlo  # real HLO text


def test_lower_train_full_ft_has_no_adapter_args():
    _, entry = aot.lower_train(C.TINY, 0, full_ft=True)
    names = [a["name"] for a in entry["args"]]
    assert not any(n.startswith(("a_", "b_")) for n in names)
    assert any(n.startswith("base_") for n in names)


def test_lower_logits_entry_consistent():
    hlo, entry = aot.lower_logits(C.TINY, 4, full_ft=False)
    assert entry["outputs"][0]["shape"] == [C.TINY.eval_batch, C.TINY.seq_len, C.TINY.vocab]
    assert len(entry["args"]) == 1 + entry["n_frozen"] + entry["n_trainable"]


def test_encoder_entries():
    hlo, entry = aot.lower_train(C.ENC_TINY, 4, full_ft=False, encoder=True, regression=True)
    assert entry["kind"] == "encoder_train"
    assert entry["regression"] is True
    names = [a["name"] for a in entry["args"]]
    assert "labels" in names and "attn_mask" in names
    assert "cls_head" in entry["trainable_names"]


def test_manifest_arg_shapes_match_param_specs():
    _, entry = aot.lower_train(C.TINY, 2, full_ft=False)
    frozen, trainable = M.param_specs(C.TINY, 2, False)
    by_name = {a["name"]: tuple(a["shape"]) for a in entry["args"]}
    for n, s in frozen + trainable:
        assert by_name[n] == tuple(s), f"{n}: manifest {by_name[n]} vs spec {s}"
    for n, s in trainable:
        assert by_name[f"m.{n}"] == tuple(s)
        assert by_name[f"v.{n}"] == tuple(s)


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not generated (run `make artifacts`)",
)
def test_emitted_manifest_files_exist():
    with open(os.path.join(ART, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["artifacts"], "empty manifest"
    for name, entry in manifest["artifacts"].items():
        path = os.path.join(ART, entry["file"])
        assert os.path.exists(path), f"missing artifact file {path}"
        assert entry["args"], f"{name} has no args"


def test_param_count_formula():
    # sanity of the config helper used in reports
    cfg = C.TINY
    dense = cfg.param_count(None)
    r4 = cfg.param_count(4)
    assert dense > r4 > 0
    d, f, l = cfg.d_model, cfg.d_ff, cfg.n_layers
    assert dense == l * (4 * d * d + 2 * d * f + f * d)
