"""Kernel-vs-reference correctness: the CORE L1 signal.

hypothesis sweeps shapes/ranks/scales; every kernel must match its pure-jnp
oracle to float32 tolerance (NF4 codes must match EXACTLY — the quantizer
is discrete).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import nf4 as knf4
from compile.kernels import pissa_linear as kpl
from compile.kernels import ref
from compile.kernels import rsvd as krsvd

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


def rnd(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# pissa_linear
# ---------------------------------------------------------------------------


@given(
    m=st.sampled_from([8, 64, 128, 256]),
    k=st.sampled_from([16, 64, 96]),
    n=st.sampled_from([8, 64, 128]),
    r=st.sampled_from([1, 4, 16]),
    seed=st.integers(0, 2**16),
)
def test_pissa_linear_matches_ref(m, k, n, r, seed):
    x = rnd(seed, (m, k))
    w = rnd(seed + 1, (k, n), 0.1)
    a = rnd(seed + 2, (k, r), 0.1)
    b = rnd(seed + 3, (r, n), 0.1)
    got = kpl.pissa_linear(x, w, a, b)
    want = ref.pissa_linear_ref(x, w, a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_pissa_linear_zero_adapter_is_dense_matmul():
    x = rnd(0, (64, 32))
    w = rnd(1, (32, 64), 0.1)
    a = jnp.zeros((32, 4))
    b = jnp.zeros((4, 64))
    got = kpl.pissa_linear(x, w, a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w), rtol=1e-5, atol=1e-6)


def test_pissa_linear_block_size_invariance():
    x = rnd(2, (256, 64))
    w = rnd(3, (64, 128), 0.1)
    a = rnd(4, (64, 8), 0.1)
    b = rnd(5, (8, 128), 0.1)
    y1 = kpl.pissa_linear(x, w, a, b, block_m=128, block_n=128)
    y2 = kpl.pissa_linear(x, w, a, b, block_m=64, block_n=32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5, atol=1e-6)


def test_vmem_accounting_under_budget():
    # The DESIGN.md §Hardware-Adaptation claim: K=4096, r=128 fits VMEM.
    assert kpl.vmem_bytes(4096, 128) < 16 * 1024 * 1024


# ---------------------------------------------------------------------------
# nf4
# ---------------------------------------------------------------------------


@given(
    ntiles=st.integers(1, 3),
    scale=st.sampled_from([0.01, 0.05, 1.0, 10.0]),
    seed=st.integers(0, 2**16),
)
def test_nf4_quantize_matches_ref(ntiles, scale, seed):
    flat = rnd(seed, (ntiles * knf4.TILE,), scale)
    codes, scales = knf4.nf4_quantize(flat)
    codes_ref, scales_ref = ref.nf4_quantize_ref(flat)
    assert bool(jnp.all(codes == codes_ref)), "codes must match exactly"
    np.testing.assert_allclose(np.asarray(scales), np.asarray(scales_ref), rtol=0, atol=0)


@given(seed=st.integers(0, 2**16))
def test_nf4_roundtrip_matches_ref(seed):
    flat = rnd(seed, (knf4.TILE,), 0.05)
    got = knf4.nf4_roundtrip(flat)
    want = ref.nf4_roundtrip_ref(flat)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


def test_nf4_roundtrip_error_bound():
    flat = rnd(7, (knf4.TILE,), 0.05)
    rt = knf4.nf4_roundtrip(flat)
    blocks = flat.reshape(-1, ref.NF4_BLOCK)
    absmax = jnp.max(jnp.abs(blocks), axis=1)
    # max gap between adjacent NF4 levels is ~0.1374 of absmax; half-gap bound
    max_gap = float(jnp.max(jnp.diff(ref.NF4_LEVELS)))
    err = jnp.abs(rt - flat).reshape(-1, ref.NF4_BLOCK)
    bound = 0.5 * max_gap * absmax[:, None] + 1e-7
    assert bool(jnp.all(err <= bound))


def test_nf4_exact_on_codebook_points():
    levels = np.asarray(ref.NF4_LEVELS)
    flat = np.tile(levels, knf4.TILE // 16).astype(np.float32) * 0.25
    rt = knf4.nf4_roundtrip(jnp.asarray(flat))
    np.testing.assert_allclose(np.asarray(rt), flat, rtol=0, atol=1e-7)


def test_nf4_zero_block():
    flat = jnp.zeros((knf4.TILE,), jnp.float32)
    rt = knf4.nf4_roundtrip(flat)
    assert bool(jnp.all(rt == 0.0))


def test_pad_to_tile():
    flat = jnp.ones((100,), jnp.float32)
    padded, n = knf4.pad_to_tile(flat)
    assert n == 100 and padded.shape[0] % knf4.TILE == 0
    assert bool(jnp.all(padded[100:] == 0))


# ---------------------------------------------------------------------------
# rsvd
# ---------------------------------------------------------------------------


@given(
    m=st.sampled_from([128, 256]),
    k=st.sampled_from([32, 64]),
    l=st.sampled_from([4, 18]),
    seed=st.integers(0, 2**16),
)
def test_tall_matmul_matches_ref(m, k, l, seed):
    w = rnd(seed, (m, k))
    q = rnd(seed + 1, (k, l))
    got = krsvd.tall_matmul(w, q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(w @ q), rtol=1e-4, atol=1e-4)


@given(rank=st.sampled_from([2, 8]), niter=st.sampled_from([1, 4]), seed=st.integers(0, 1000))
def test_fast_svd_matches_ref(rank, niter, seed):
    w = rnd(seed, (128, 64), 0.1)
    key = jax.random.PRNGKey(seed)
    u1, s1, vt1 = krsvd.fast_svd(w, rank, niter, key)
    u2, s2, vt2 = ref.fast_svd_ref(w, rank, niter, key)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4, atol=1e-5)
    # subspace agreement (up to sign): |u1ᵀu2| diag close to 1
    d = jnp.abs(jnp.einsum("mi,mi->i", u1, u2))
    np.testing.assert_allclose(np.asarray(d), np.ones(rank), atol=1e-3)


def test_fast_svd_approaches_exact_svd():
    w = rnd(11, (128, 64), 0.1)
    s_exact = jnp.linalg.svd(w, compute_uv=False)
    _, s_fast, _ = krsvd.fast_svd(w, 8, 8, jax.random.PRNGKey(0))
    np.testing.assert_allclose(np.asarray(s_fast), np.asarray(s_exact[:8]), rtol=5e-3)


def test_pissa_init_reconstructs_exactly():
    # Eq. 5: A·B + W_res == W (the residual absorbs sketch error).
    w = rnd(13, (128, 64), 0.1)
    a, b, res = krsvd.pissa_init(w, 8, 2, jax.random.PRNGKey(1))
    err = jnp.linalg.norm(a @ b + res - w) / jnp.linalg.norm(w)
    assert float(err) < 1e-6


def test_pissa_init_adapter_outweighs_residual():
    # Principal components carry more Frobenius mass than the residual
    # on a decaying-spectrum matrix.
    key = jax.random.PRNGKey(2)
    u, _ = jnp.linalg.qr(jax.random.normal(key, (96, 64)))
    v, _ = jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(3), (64, 64)))
    s = 1.0 / (1.0 + jnp.arange(64.0))
    w = (u * s[None, :]) @ v.T
    a, b, res = krsvd.pissa_init(w, 8, 4, jax.random.PRNGKey(4))
    assert float(jnp.linalg.norm(a @ b)) > float(jnp.linalg.norm(res))
