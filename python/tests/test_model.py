"""L2 model invariants: shapes, adapter-freezing semantics, loss behavior,
AdamW correctness, encoder path, pallas/jnp agreement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs as C
from compile import model as M

CFG = C.TINY
ENC = C.ENC_TINY


def make_state(cfg, rank, full_ft, encoder=False, seed=0):
    frozen, trainable = M.init_params(cfg, rank, full_ft, jax.random.PRNGKey(seed), encoder=encoder)
    m = {k: jnp.zeros_like(v) for k, v in trainable.items()}
    v = {k: jnp.zeros_like(t) for k, t in trainable.items()}
    return frozen, trainable, m, v


def flat_args(fn_specs, frozen, trainable, m, v, head):
    _, fs, ts = fn_specs
    return head + [frozen[n] for n, _ in fs] + [trainable[n] for n, _ in ts] + [m[n] for n, _ in ts] + [v[n] for n, _ in ts]


def decoder_batch(cfg, seed=1):
    tokens = jax.random.randint(jax.random.PRNGKey(seed), (cfg.batch, cfg.seq_len), 0, cfg.vocab)
    mask = jnp.ones((cfg.batch, cfg.seq_len), jnp.float32)
    return tokens, mask


def test_param_specs_orders_are_stable():
    f1, t1 = M.param_specs(CFG, 4, False)
    f2, t2 = M.param_specs(CFG, 4, False)
    assert f1 == f2 and t1 == t2
    names = [n for n, _ in f1 + t1]
    assert len(names) == len(set(names)), "duplicate param names"


def test_full_ft_has_no_adapters():
    f, t = M.param_specs(CFG, 4, True)
    tnames = [n for n, _ in t]
    # Full-FT trains embed + lm_head + the dense linears — no adapters.
    assert not any(n.startswith(("a_", "b_")) for n in tnames)
    assert "embed" in tnames and "lm_head" in tnames
    assert sum(n.startswith("base_") for n in tnames) == len(M.LINEARS)
    assert not any(n.startswith(("a_", "b_")) for n, _ in f)


def test_logits_shape_and_finite():
    frozen, trainable, _, _ = make_state(CFG, 4, False)
    tokens, _ = decoder_batch(CFG)
    logits = M.logits_fn({**frozen, **trainable}, tokens, CFG)
    assert logits.shape == (CFG.batch, CFG.seq_len, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_adapter_zero_b_matches_base_only():
    # LoRA init: B = 0 ⇒ logits identical to the frozen base model.
    frozen, trainable, _, _ = make_state(CFG, 4, False)
    tokens, _ = decoder_batch(CFG)
    with_adapter = M.logits_fn({**frozen, **trainable}, tokens, CFG)
    dense_params = dict(frozen)
    zero_t = {k: jnp.zeros_like(v) if k.startswith("a_") else v for k, v in trainable.items()}
    no_adapter = M.logits_fn({**dense_params, **zero_t}, tokens, CFG)
    np.testing.assert_allclose(np.asarray(with_adapter), np.asarray(no_adapter), atol=1e-5)


def test_loss_mask_controls_loss():
    frozen, trainable, _, _ = make_state(CFG, 4, False)
    params = {**frozen, **trainable}
    tokens, mask = decoder_batch(CFG)
    full = M.lm_loss(params, tokens, mask, CFG)
    # Masking out everything except one position changes the loss.
    mask2 = mask.at[:, : CFG.seq_len // 2].set(0.0)
    half = M.lm_loss(params, tokens, mask2, CFG)
    assert full.shape == () and half.shape == ()
    assert abs(float(full) - float(half)) > 1e-9


def test_train_step_only_updates_trainables_and_loss_decreases():
    rank = 4
    spec = M.make_train_step(CFG, rank, full_ft=False)
    fn = jax.jit(spec[0])
    frozen, trainable, m, v = make_state(CFG, rank, False)
    tokens, mask = decoder_batch(CFG)
    ts = spec[2]
    nt = len(ts)
    losses = []
    state_t, state_m, state_v = trainable, m, v
    for step in range(1, 9):
        args = flat_args(spec, frozen, state_t, state_m, state_v,
                         [tokens, mask, jnp.float32(5e-3), jnp.float32(step)])
        out = fn(*args)
        losses.append(float(out[0]))
        vals = out[2:]
        state_t = {n: vals[i] for i, (n, _) in enumerate(ts)}
        state_m = {n: vals[nt + i] for i, (n, _) in enumerate(ts)}
        state_v = {n: vals[2 * nt + i] for i, (n, _) in enumerate(ts)}
    assert losses[-1] < losses[0], f"loss did not decrease: {losses}"
    # grad flows into adapters: A and B must have moved.
    assert float(jnp.linalg.norm(state_t["b_q"] - trainable["b_q"])) > 0


def test_gradients_nonzero_for_adapters():
    rank = 4
    frozen, trainable, _, _ = make_state(CFG, rank, False)
    tokens, mask = decoder_batch(CFG)

    def loss_fn(t):
        return M.lm_loss({**frozen, **t}, tokens, mask, CFG)

    grads = jax.grad(loss_fn)(trainable)
    # With B=0 at init, dL/dB = Aᵀ Xᵀ dL/dY ≠ 0 but dL/dA = Xᵀ dL/dY Bᵀ = 0
    # (the paper's slow-LoRA-start argument!).
    assert float(jnp.linalg.norm(grads["b_q"])) > 0
    assert float(jnp.linalg.norm(grads["a_q"])) == pytest.approx(0.0, abs=1e-12)


def test_pissa_style_init_has_nonzero_gradients_everywhere():
    # Give B nonzero (PiSSA-style) values: now BOTH A and B receive grads.
    rank = 4
    frozen, trainable, _, _ = make_state(CFG, rank, False, seed=3)
    trainable = dict(trainable)
    key = jax.random.PRNGKey(9)
    for k in list(trainable):
        if k.startswith("b_"):
            trainable[k] = 0.02 * jax.random.normal(key, trainable[k].shape)
    tokens, mask = decoder_batch(CFG)

    def loss_fn(t):
        return M.lm_loss({**frozen, **t}, tokens, mask, CFG)

    grads = jax.grad(loss_fn)(trainable)
    assert float(jnp.linalg.norm(grads["a_q"])) > 0
    assert float(jnp.linalg.norm(grads["b_q"])) > 0


def test_adamw_matches_manual_single_param():
    g = jnp.array([0.5, -1.0])
    t = {"w": jnp.array([1.0, 2.0])}
    m = {"w": jnp.zeros(2)}
    v = {"w": jnp.zeros(2)}
    new_t, new_m, new_v = M.adamw_update({"w": g}, t, m, v, lr=0.1, step=1.0)
    mhat = (0.1 * g) / (1 - 0.9)
    vhat = (0.001 * g * g) / (1 - 0.999)
    want = t["w"] - 0.1 * mhat / (jnp.sqrt(vhat) + M.ADAM_EPS)
    np.testing.assert_allclose(np.asarray(new_t["w"]), np.asarray(want), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(new_m["w"]), np.asarray(0.1 * g), rtol=1e-6)


def test_encoder_shapes_and_loss():
    frozen, trainable, _, _ = make_state(ENC, 4, False, encoder=True)
    params = {**frozen, **trainable}
    tokens = jax.random.randint(jax.random.PRNGKey(5), (ENC.batch, ENC.seq_len), 0, ENC.vocab)
    amask = jnp.ones((ENC.batch, ENC.seq_len), jnp.float32)
    logits = M.encoder_logits_fn(params, tokens, amask, ENC)
    assert logits.shape == (ENC.batch, ENC.n_classes)
    labels = jnp.zeros((ENC.batch,), jnp.int32)
    l_cls = M.encoder_loss(params, tokens, amask, labels, ENC, regression=False)
    l_reg = M.encoder_loss(params, tokens, amask, labels, ENC, regression=True)
    assert jnp.isfinite(l_cls) and jnp.isfinite(l_reg)


def test_encoder_train_step_decreases_loss():
    spec = M.make_train_step(ENC, 4, full_ft=False, encoder=True)
    fn = jax.jit(spec[0])
    frozen, trainable, m, v = make_state(ENC, 4, False, encoder=True)
    tokens = jax.random.randint(jax.random.PRNGKey(6), (ENC.batch, ENC.seq_len), 0, ENC.vocab)
    amask = jnp.ones((ENC.batch, ENC.seq_len), jnp.float32)
    labels = (tokens[:, 0] % ENC.n_classes).astype(jnp.int32)  # learnable signal
    ts = spec[2]
    nt = len(ts)
    state = (trainable, m, v)
    losses = []
    for step in range(1, 13):
        args = flat_args(spec, frozen, *state,
                         head=[tokens, amask, labels, jnp.float32(2e-2), jnp.float32(step)])
        out = fn(*args)
        losses.append(float(out[0]))
        vals = out[2:]
        state = (
            {n: vals[i] for i, (n, _) in enumerate(ts)},
            {n: vals[nt + i] for i, (n, _) in enumerate(ts)},
            {n: vals[2 * nt + i] for i, (n, _) in enumerate(ts)},
        )
    assert losses[-1] < losses[0], f"encoder loss did not decrease: {losses}"


def test_pallas_and_jnp_paths_agree():
    frozen, trainable, _, _ = make_state(CFG, 4, False, seed=8)
    # PiSSA-style nonzero B so the rank path actually contributes.
    key = jax.random.PRNGKey(10)
    trainable = {
        k: (0.02 * jax.random.normal(key, val.shape) if k.startswith("b_") else val)
        for k, val in trainable.items()
    }
    params = {**frozen, **trainable}
    tokens, _ = decoder_batch(CFG, seed=9)
    y_jnp = M.logits_fn(params, tokens, CFG, use_pallas=False)
    y_pal = M.logits_fn(params, tokens, CFG, use_pallas=True)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_jnp), rtol=2e-4, atol=2e-4)


def test_rope_preserves_norm():
    x = jax.random.normal(jax.random.PRNGKey(11), (2, 8, 2, 16))
    pos = jnp.broadcast_to(jnp.arange(8)[None, :], (2, 8))
    y = M.rope(x, pos)
    np.testing.assert_allclose(
        np.asarray(jnp.linalg.norm(y, axis=-1)),
        np.asarray(jnp.linalg.norm(x, axis=-1)),
        rtol=1e-5,
    )


def test_causal_masking():
    # Changing a future token must not affect past logits.
    frozen, trainable, _, _ = make_state(CFG, 4, False, seed=12)
    params = {**frozen, **trainable}
    tokens, _ = decoder_batch(CFG, seed=13)
    logits1 = M.logits_fn(params, tokens, CFG)
    tokens2 = tokens.at[:, -1].set((tokens[:, -1] + 1) % CFG.vocab)
    logits2 = M.logits_fn(params, tokens2, CFG)
    np.testing.assert_allclose(
        np.asarray(logits1[:, :-1]), np.asarray(logits2[:, :-1]), atol=1e-5
    )
