"""L1 Pallas kernels (interpret=True) + pure-jnp reference oracles."""

from . import nf4, pissa_linear, ref, rsvd  # noqa: F401
