"""Pure-jnp oracles for the Pallas kernels (the CORE correctness signal).

Every kernel in this package has a reference implementation here, written
with plain jax.numpy so it is obviously correct. pytest compares kernel
outputs against these under hypothesis-driven shape/rank sweeps, and the
rust side compares its own NF4/SVD implementations against golden files
generated from these functions.
"""

import jax.numpy as jnp

# The 16 NF4 codebook levels (bitsandbytes' exact constants) — keep in
# sync with rust/src/quant/nf4.rs::NF4_LEVELS.
NF4_LEVELS = jnp.array(
    [
        -1.0,
        -0.6961928009986877,
        -0.5250730514526367,
        -0.39491748809814453,
        -0.28444138169288635,
        -0.18477343022823334,
        -0.09105003625154495,
        0.0,
        0.07958029955625534,
        0.16093020141124725,
        0.24611230194568634,
        0.33791524171829224,
        0.44070982933044434,
        0.5626170039176941,
        0.7229568362236023,
        1.0,
    ],
    dtype=jnp.float32,
)

NF4_BLOCK = 64  # values per quantization block


def pissa_linear_ref(x, w_base, a, b):
    """Adapter-form linear: y = x @ w_base + (x @ a) @ b (paper Eq. 5)."""
    return x @ w_base + (x @ a) @ b


def nf4_quantize_ref(flat):
    """Blockwise-absmax NF4 quantization of a flat f32 vector.

    Returns (codes int32 [n], scales f32 [n / NF4_BLOCK]). Length must be a
    multiple of NF4_BLOCK (callers pad).
    """
    n = flat.shape[0]
    assert n % NF4_BLOCK == 0, "pad to a multiple of NF4_BLOCK"
    blocks = flat.reshape(n // NF4_BLOCK, NF4_BLOCK)
    scales = jnp.max(jnp.abs(blocks), axis=1)
    inv = jnp.where(scales > 0, 1.0 / scales, 0.0)
    normed = blocks * inv[:, None]
    # nearest codebook level
    dist = jnp.abs(normed[:, :, None] - NF4_LEVELS[None, None, :])
    codes = jnp.argmin(dist, axis=-1).astype(jnp.int32)
    return codes.reshape(n), scales


def nf4_dequantize_ref(codes, scales):
    """Inverse of nf4_quantize_ref."""
    n = codes.shape[0]
    vals = NF4_LEVELS[codes].reshape(n // NF4_BLOCK, NF4_BLOCK)
    return (vals * scales[:, None]).reshape(n)


def nf4_roundtrip_ref(flat):
    codes, scales = nf4_quantize_ref(flat)
    return nf4_dequantize_ref(codes, scales)


def power_iter_ref(w, q):
    """One Halko subspace half-step: Y = W @ Q (tall W, thin Q)."""
    return w @ q


def fast_svd_ref(w, rank, niter, key):
    """Reference randomized SVD (Halko) used to validate rsvd kernels and
    the rust implementation's singular values."""
    import jax

    m, n = w.shape
    l = min(rank + 10, min(m, n))
    omega = jax.random.normal(key, (n, l), dtype=w.dtype)
    y = w @ omega
    for _ in range(niter):
        q, _ = jnp.linalg.qr(y)
        z, _ = jnp.linalg.qr(w.T @ q)
        y = w @ z
    q, _ = jnp.linalg.qr(y)
    b = q.T @ w
    u_small, s, vt = jnp.linalg.svd(b, full_matrices=False)
    u = q @ u_small
    return u[:, :rank], s[:rank], vt[:rank, :]


def pissa_init_ref(w, rank, niter, key):
    """PiSSA init per Eq. 2-4: A = U sqrt(S), B = sqrt(S) Vt, res = W - AB."""
    u, s, vt = fast_svd_ref(w, rank, niter, key)
    root = jnp.sqrt(s)
    a = u * root[None, :]
    b = root[:, None] * vt
    res = w - a @ b
    return a, b, res
