"""L1 Pallas kernel: fused adapter-form linear  y = x·W_base + (x·A)·B.

This is the paper's compute hot-spot: every linear layer of a
PiSSA/LoRA-adapted model evaluates Eq. 5. On GPU the reference
implementations launch two thin GEMMs (x·A then ·B) on top of the dense
x·W; on TPU the right shape is ONE kernel per output tile that keeps the
`x` tile resident in VMEM and runs all three contractions back-to-back on
the MXU, never materializing x·A in HBM.

Tiling (see DESIGN.md §Hardware-Adaptation):
  grid = (M/bm, N/bn); each program instance loads
    x_tile  [bm, K]   (VMEM)
    w_tile  [K, bn]   (VMEM)
    a       [K, r]    (VMEM, broadcast across the n-grid)
    b_tile  [r, bn]   (VMEM)
  and computes  o = x_tile@w_tile + (x_tile@a)@b_tile  entirely in VMEM.
  With bm = bn = 128 and r ≤ 128 this maps onto 128×128 MXU passes.
  VMEM bytes = 4·(bm·K + K·bn + K·r + r·bn + bm·bn); for K = 4096,
  bm = bn = r = 128 that is ≈ 4.5 MiB — comfortably under the 16 MiB/core
  budget, so K does not need an inner grid axis until K > ~12k.

interpret=True is mandatory here: the CPU PJRT client cannot execute
Mosaic custom-calls, so the kernel body is traced to plain HLO (the same
numerics, minus the explicit memory placement).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, a_ref, b_ref, o_ref):
    x = x_ref[...]
    # Dense path: [bm, K] @ [K, bn] on the MXU.
    dense = jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)
    # Low-rank path: [bm, K] @ [K, r] @ [r, bn]; xa stays in registers/VMEM.
    xa = jnp.dot(x, a_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = dense + jnp.dot(xa, b_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def pissa_linear(x, w_base, a, b, block_m=128, block_n=128):
    """Fused y = x @ w_base + (x @ a) @ b.

    x: [M, K], w_base: [K, N], a: [K, r], b: [r, N] -> y: [M, N].
    M must divide by block_m and N by block_n (callers pad; the AOT model
    always uses aligned shapes).
    """
    m, k = x.shape
    k2, n = w_base.shape
    assert k == k2, f"inner dim mismatch {k} vs {k2}"
    r = a.shape[1]
    assert a.shape == (k, r) and b.shape == (r, n)
    bm = min(block_m, m)
    bn = min(block_n, n)
    assert m % bm == 0 and n % bn == 0, f"pad M={m}, N={n} to multiples of ({bm},{bn})"

    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),  # x row-tile
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),  # w col-tile
            pl.BlockSpec((k, r), lambda i, j: (0, 0)),  # a (broadcast)
            pl.BlockSpec((r, bn), lambda i, j: (0, j)),  # b col-tile
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, w_base, a, b)


def vmem_bytes(k, r, block_m=128, block_n=128):
    """Analytic VMEM footprint of one program instance (f32), used by the
    §Perf roofline estimate in EXPERIMENTS.md."""
    return 4 * (block_m * k + k * block_n + k * r + r * block_n + block_m * block_n)


def mxu_flops(m, n, k, r):
    """FLOPs per call: dense 2mnk + low-rank 2mkr + 2mrn."""
    return 2 * m * n * k + 2 * m * k * r + 2 * m * r * n
