"""L1 Pallas kernel: the randomized-SVD (Halko) power-iteration hot loop.

Fast SVD's cost is dominated by the tall-matrix products Y = W·Q and
Z = Wᵀ·Q' — everything else (thin QR, the (r+p)×n small SVD) is tiny. We
express the tall product as a row-tiled Pallas kernel: each program
instance owns a [bm, K] strip of W and produces a [bm, L] strip of Y with
one MXU pass; Q (n×l, thin) is broadcast to every instance and stays
VMEM-resident across the whole grid.

The host-side `fast_svd` chains this kernel with jnp.linalg.qr /
jnp.linalg.svd on the small matrices — those are O(n·l²) and not the
hot-spot (Table 4's timing difference comes from the tall GEMMs).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(w_ref, q_ref, y_ref):
    y_ref[...] = jnp.dot(w_ref[...], q_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_m",))
def tall_matmul(w, q, block_m=128):
    """Y = W @ Q for tall W [M, K] and thin Q [K, L]; M % block_m == 0
    (or M < block_m, in which case a single instance runs)."""
    m, k = w.shape
    k2, l = q.shape
    assert k == k2
    bm = min(block_m, m)
    assert m % bm == 0, f"pad M={m} to a multiple of {bm}"
    return pl.pallas_call(
        _matmul_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((k, l), lambda i: (0, 0)),  # Q broadcast, VMEM-resident
        ],
        out_specs=pl.BlockSpec((bm, l), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, l), jnp.float32),
        interpret=True,
    )(w, q)


def fast_svd(w, rank, niter, key, block_m=128):
    """Halko randomized SVD with the Pallas kernel on the hot GEMMs.

    Matches ref.fast_svd_ref numerically (same algorithm, same sketch).
    """
    m, n = w.shape
    l = min(rank + 10, min(m, n))
    omega = jax.random.normal(key, (n, l), dtype=w.dtype)
    y = tall_matmul(w, omega, block_m=block_m) if m % min(block_m, m) == 0 else w @ omega
    wt = w.T
    for _ in range(niter):
        q, _ = jnp.linalg.qr(y)
        z, _ = jnp.linalg.qr(tall_matmul(wt, q, block_m=block_m) if n % min(block_m, n) == 0 else wt @ q)
        y = tall_matmul(w, z, block_m=block_m) if m % min(block_m, m) == 0 else w @ z
    q, _ = jnp.linalg.qr(y)
    b = q.T @ w
    u_small, s, vt = jnp.linalg.svd(b, full_matrices=False)
    return (q @ u_small)[:, :rank], s[:rank], vt[:rank, :]


def pissa_init(w, rank, niter, key):
    """PiSSA init (Eq. 2-4) on top of the kernel-backed fast SVD."""
    u, s, vt = fast_svd(w, rank, niter, key)
    root = jnp.sqrt(s)
    a = u * root[None, :]
    b = root[:, None] * vt
    return a, b, w - a @ b
