"""L1 Pallas kernels: blockwise-absmax NF4 quantize / dequantize.

NF4 on TPU is a 16-entry VMEM table lookup plus a vector scale — there is
no tensor-core analog to port from the CUDA implementation; the natural
mapping is grid-tiled elementwise work where each program instance owns a
contiguous run of quantization blocks (TILE values = TILE/64 blocks), so
the absmax reduction never crosses a tile boundary.

Layout notes:
  * codes are produced as int32 (one per value). Bit-packing two codes per
    byte is a storage-side concern handled by the rust `quant::nf4` module;
    doing it inside the kernel would only save HBM bandwidth on the store
    and cannot be expressed portably in interpret mode.
  * the quantize kernel emits codes AND scales; dequantize consumes both.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NF4_BLOCK, NF4_LEVELS

# Values per program instance. 4096 values = 64 NF4 blocks per tile.
TILE = 4096


def _quant_kernel(x_ref, levels_ref, codes_ref, scales_ref):
    x = x_ref[...]  # [TILE]
    levels = levels_ref[...]  # [16] — the VMEM-resident LUT
    blocks = x.reshape(TILE // NF4_BLOCK, NF4_BLOCK)
    absmax = jnp.max(jnp.abs(blocks), axis=1)  # [TILE/64]
    inv = jnp.where(absmax > 0, 1.0 / absmax, 0.0)
    normed = blocks * inv[:, None]
    # Nearest codebook entry: 16-wide broadcast compare.
    dist = jnp.abs(normed[:, :, None] - levels[None, None, :])
    codes_ref[...] = jnp.argmin(dist, axis=-1).astype(jnp.int32).reshape(TILE)
    scales_ref[...] = absmax


def _dequant_kernel(codes_ref, scales_ref, levels_ref, out_ref):
    codes = codes_ref[...]
    vals = levels_ref[...][codes].reshape(TILE // NF4_BLOCK, NF4_BLOCK)
    out_ref[...] = (vals * scales_ref[...][:, None]).reshape(TILE)


@jax.jit
def nf4_quantize(flat):
    """Quantize a flat f32 vector (len divisible by TILE) to NF4.

    Returns (codes int32 [n], scales f32 [n/NF4_BLOCK]).
    """
    (n,) = flat.shape
    assert n % TILE == 0, f"pad to a multiple of {TILE}"
    grid = (n // TILE,)
    return pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((16,), lambda i: (0,)),  # LUT broadcast
        ],
        out_specs=[
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((TILE // NF4_BLOCK,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n // NF4_BLOCK,), jnp.float32),
        ],
        interpret=True,
    )(flat, NF4_LEVELS)


@jax.jit
def nf4_dequantize(codes, scales):
    """Inverse of nf4_quantize."""
    (n,) = codes.shape
    assert n % TILE == 0
    grid = (n // TILE,)
    return pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((TILE // NF4_BLOCK,), lambda i: (i,)),
            pl.BlockSpec((16,), lambda i: (0,)),  # LUT broadcast
        ],
        out_specs=pl.BlockSpec((TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(codes, scales, NF4_LEVELS)


@functools.partial(jax.jit)
def nf4_roundtrip(flat):
    """deq(quant(x)) — the nf4(·) operator of the paper's Eq. 6/8."""
    codes, scales = nf4_quantize(flat)
    return nf4_dequantize(codes, scales)


def pad_to_tile(flat):
    """Zero-pad a flat array to the kernel's TILE multiple; returns
    (padded, original_len)."""
    n = flat.shape[0]
    pad = (-n) % TILE
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat, n
