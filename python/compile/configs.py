"""Model/artifact configurations shared by the AOT pipeline and tests.

The rust side re-reads these numbers from artifacts/manifest.json — this
file is the single source of truth for shapes. Keep token budget small:
every (config, rank) pair lowers its own HLO artifact, and `make
artifacts` must stay in the minutes range on CPU.
"""

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int = 320  # 256 bytes + specials, rounded up for alignment
    d_model: int = 64
    n_layers: int = 2
    n_heads: int = 2
    d_ff: int = 128
    seq_len: int = 64
    batch: int = 8
    # Which ranks get adapter train artifacts.
    ranks: tuple = (4,)
    # Lower the logits artifact with this batch (greedy decode batch).
    eval_batch: int = 4

    @property
    def head_dim(self):
        return self.d_model // self.n_heads

    def param_count(self, rank=None):
        """Trainable parameter count: dense linears if rank is None,
        adapters of the given rank otherwise."""
        d, f, l = self.d_model, self.d_ff, self.n_layers
        if rank is None:
            per_layer = 4 * d * d + 2 * d * f + f * d
            return l * per_layer
        per_layer = 4 * (d + d) * rank + 2 * (d + f) * rank + (f + d) * rank
        return l * per_layer


@dataclass(frozen=True)
class EncoderConfig:
    name: str
    vocab: int = 320
    d_model: int = 64
    n_layers: int = 2
    n_heads: int = 2
    d_ff: int = 128
    seq_len: int = 32
    batch: int = 16
    n_classes: int = 3  # >= max over NLU tasks; regression uses index 0
    ranks: tuple = (8,)

    @property
    def head_dim(self):
        return self.d_model // self.n_heads


# The artifact matrix. `tiny` drives tests and quick examples, `small`
# drives the experiment sweeps, `e2e` is the end-to-end driver's model
# (~7M trainable dense params — the largest that trains a few hundred
# steps in CPU-minutes).
TINY = ModelConfig(name="tiny", ranks=(2, 4))
SMALL = ModelConfig(
    name="small",
    d_model=128,
    n_layers=4,
    n_heads=4,
    d_ff=256,
    seq_len=96,
    batch=8,
    ranks=(1, 2, 4, 8, 16, 32),
)
E2E = ModelConfig(
    name="e2e",
    d_model=256,
    n_layers=6,
    n_heads=8,
    d_ff=512,
    seq_len=128,
    batch=8,
    ranks=(8,),
)

ENC_TINY = EncoderConfig(name="enc_tiny", ranks=(4,))
ENC_SMALL = EncoderConfig(
    name="enc_small", d_model=96, n_layers=3, n_heads=3, d_ff=192, seq_len=48, batch=16, ranks=(8,)
)

DECODERS = [TINY, SMALL, E2E]
ENCODERS = [ENC_TINY, ENC_SMALL]

BY_NAME = {c.name: c for c in DECODERS + ENCODERS}
