"""L2: the JAX model — a llama-style decoder (and a bidirectional encoder
for the NLU tasks) with every linear layer in adapter form

    y = x @ W_base + (x @ A) @ B            (paper Eq. 5)

where W_base is the frozen matrix (W for LoRA, W_res for PiSSA, their NF4
round-trips for the Q-variants — the *rust* side decides what to put
there) and (A, B) are the trainable adapter factors. The same code also
lowers a full-fine-tuning variant where the dense linears are trainable
and no adapter exists.

Everything here is build-time only: `aot.py` lowers `train_step` /
`logits_fn` / encoder variants to HLO text once, and the rust coordinator
executes them through PJRT. The Pallas kernel path (`use_pallas=True`)
lowers the adapter linears through kernels.pissa_linear so the interpret-
mode kernel lands in the same HLO; the default path uses plain jnp ops
(identical numerics, leaner HLO) — both are artifact variants and the
tests assert they agree.

Parameter layout (all stacked over layers, scan-friendly):
  frozen:    embed [V,D], lm_head [D,V], attn_norm [L,D], mlp_norm [L,D],
             final_norm [D], base_{q,k,v,o} [L,D,D],
             base_{gate,up} [L,D,F], base_down [L,F,D]
  adapters:  a_{q,k,v,o} [L,D,R],  b_{q,k,v,o} [L,R,D],
             a_{gate,up} [L,D,R],  b_{gate,up} [L,R,F],
             a_down      [L,F,R],  b_down      [L,R,D]
  full-FT:   the seven base_* tensors move to the trainable set.

AdamW (paper recipe: no weight decay, cosine schedule handled by rust,
lr passed per step) with standard bias correction.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels.pissa_linear import pissa_linear as _pallas_linear

# Linear-layer types, in canonical order (paper's Q/K/V/O/Gate/Up/Down).
LINEARS = ("q", "k", "v", "o", "gate", "up", "down")

FROZEN_ALWAYS = ("embed", "lm_head", "attn_norm", "mlp_norm", "final_norm")


def linear_shapes(cfg):
    """(in_dim, out_dim) per linear type."""
    d, f = cfg.d_model, cfg.d_ff
    return {
        "q": (d, d),
        "k": (d, d),
        "v": (d, d),
        "o": (d, d),
        "gate": (d, f),
        "up": (d, f),
        "down": (f, d),
    }


def param_specs(cfg, rank, full_ft, encoder=False):
    """Ordered (name, shape) lists: (frozen, trainable).

    The order here IS the HLO argument order — rust/model/params.rs
    mirrors it via manifest.json.
    """
    d, v, l = cfg.d_model, cfg.vocab, cfg.n_layers
    shapes = linear_shapes(cfg)
    head = ("lm_head", (d, v)) if not encoder else ("cls_base", (d, cfg.n_classes))
    frozen = [
        ("attn_norm", (l, d)),
        ("mlp_norm", (l, d)),
        ("final_norm", (d,)),
    ]
    trainable = []
    if full_ft and not encoder:
        # Full fine-tuning (and pre-training, which reuses this artifact)
        # trains the embedding and output head too; norms stay frozen at 1
        # to keep the trainable set purely matrix-shaped.
        trainable.append(("embed", (v, d)))
        trainable.append(head)
    else:
        frozen.insert(0, ("embed", (v, d)))
        frozen.insert(1, head)
    if encoder:
        # Classification head is always trainable on NLU (paper App. I).
        trainable.append(("cls_head", (d, cfg.n_classes)))
    for name in LINEARS:
        m, n = shapes[name]
        if full_ft:
            trainable.append((f"base_{name}", (l, m, n)))
        else:
            frozen.append((f"base_{name}", (l, m, n)))
            trainable.append((f"a_{name}", (l, m, rank)))
            trainable.append((f"b_{name}", (l, rank, n)))
    return frozen, trainable


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------


def rms_norm(x, gain, eps=1e-6):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * gain


def rope(x, positions):
    """Rotary position embedding over the head dim (standard llama RoPE)."""
    # x: [B, T, H, Hd]
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions[:, :, None].astype(jnp.float32) * freqs[None, None, :]  # [B,T,half]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def adapter_linear(x, w, a, b, use_pallas=False):
    """y = x·w + (x·a)·b over the last dim of x (rank path skipped when
    a is None — full-FT)."""
    if a is None:
        return x @ w
    if use_pallas:
        lead = x.shape[:-1]
        k = x.shape[-1]
        x2 = x.reshape(-1, k)
        m = x2.shape[0]
        # Tile sizes must divide the operand dims: fall back to jnp when the
        # flattened batch is not 8-aligned (never happens in AOT shapes).
        if m % 8 == 0 and w.shape[1] % 8 == 0:
            bm = min(128, m)
            while m % bm:
                bm //= 2
            bn = min(128, w.shape[1])
            while w.shape[1] % bn:
                bn //= 2
            y = _pallas_linear(x2, w, a, b, block_m=bm, block_n=bn)
            return y.reshape(*lead, w.shape[1])
    return x @ w + (x @ a) @ b


def attention(x, layer, positions, causal, cfg, use_pallas):
    """Multi-head attention with RoPE; adapter-form projections."""
    b, t, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim

    def proj(name):
        return adapter_linear(
            x, layer[f"base_{name}"], layer.get(f"a_{name}"), layer.get(f"b_{name}"), use_pallas
        )

    q = proj("q").reshape(b, t, h, hd)
    k = proj("k").reshape(b, t, h, hd)
    v = proj("v").reshape(b, t, h, hd)
    q = rope(q, positions)
    k = rope(k, positions)
    scores = jnp.einsum("bthd,bshd->bhts", q, k) / jnp.sqrt(float(hd))
    if causal:
        mask = jnp.tril(jnp.ones((t, t), dtype=bool))
        scores = jnp.where(mask[None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhts,bshd->bthd", probs, v).reshape(b, t, d)
    return adapter_linear(
        out, layer["base_o"], layer.get("a_o"), layer.get("b_o"), use_pallas
    )


def mlp(x, layer, use_pallas):
    """SwiGLU MLP with adapter-form projections."""
    gate = adapter_linear(x, layer["base_gate"], layer.get("a_gate"), layer.get("b_gate"), use_pallas)
    up = adapter_linear(x, layer["base_up"], layer.get("a_up"), layer.get("b_up"), use_pallas)
    act = jax.nn.silu(gate) * up
    return adapter_linear(act, layer["base_down"], layer.get("a_down"), layer.get("b_down"), use_pallas)


def forward(params, tokens, cfg, causal=True, use_pallas=False):
    """Token ids [B, T] -> hidden states [B, T, D] after final norm."""
    b, t = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(t)[None, :], (b, t))

    # Stack per-layer params for scan.
    layer_keys = [k for k in params if k.startswith(("base_", "a_", "b_")) or k in ("attn_norm", "mlp_norm")]

    def body(x, per_layer):
        h = x + attention(
            rms_norm(x, per_layer["attn_norm"][None, None, :]),
            per_layer,
            positions,
            causal,
            cfg,
            use_pallas,
        )
        h2 = h + mlp(rms_norm(h, per_layer["mlp_norm"][None, None, :]), per_layer, use_pallas)
        return h2, None

    xs = {k: params[k] for k in layer_keys}
    x, _ = jax.lax.scan(body, x, xs)
    return rms_norm(x, params["final_norm"][None, None, :])


def logits_fn(params, tokens, cfg, use_pallas=False):
    """Causal LM logits [B, T, V]."""
    h = forward(params, tokens, cfg, causal=True, use_pallas=use_pallas)
    return h @ params["lm_head"]


def lm_loss(params, tokens, loss_mask, cfg, use_pallas=False):
    """Response-masked causal cross-entropy (Alpaca/QLoRA recipe: loss only
    on response tokens — the mask is produced by the rust batcher)."""
    logits = logits_fn(params, tokens, cfg, use_pallas=use_pallas)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    mask = loss_mask[:, 1:]
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# encoder (NLU / GLUE-like)
# ---------------------------------------------------------------------------


def encoder_logits_fn(params, tokens, attn_mask, cfg, use_pallas=False):
    """Bidirectional encoder -> masked-mean pool -> class logits [B, C].

    cls_base is a frozen random head base; cls_head is the trainable
    delta (head = cls_base + cls_head), so the trainable set stays uniform
    across strategies.
    """
    h = forward(params, tokens, cfg, causal=False, use_pallas=use_pallas)
    m = attn_mask[:, :, None]
    pooled = jnp.sum(h * m, axis=1) / jnp.maximum(jnp.sum(m, axis=1), 1.0)
    head = params["cls_base"] + params["cls_head"]
    return pooled @ head


def encoder_loss(params, tokens, attn_mask, labels, cfg, regression=False, use_pallas=False):
    logits = encoder_logits_fn(params, tokens, attn_mask, cfg, use_pallas=use_pallas)
    if regression:
        pred = logits[:, 0]
        return jnp.mean((pred - labels.astype(jnp.float32)) ** 2)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None].astype(jnp.int32), axis=-1))


# ---------------------------------------------------------------------------
# AdamW train step
# ---------------------------------------------------------------------------

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


def adamw_update(grads, trainable, m, v, lr, step):
    """One AdamW step (weight decay 0 per the paper's recipe)."""
    b1t = ADAM_B1**step
    b2t = ADAM_B2**step
    new_t, new_m, new_v = {}, {}, {}
    for key in trainable:
        g = grads[key]
        nm = ADAM_B1 * m[key] + (1 - ADAM_B1) * g
        nv = ADAM_B2 * v[key] + (1 - ADAM_B2) * g * g
        mhat = nm / (1 - b1t)
        vhat = nv / (1 - b2t)
        new_t[key] = trainable[key] - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
        new_m[key] = nm
        new_v[key] = nv
    return new_t, new_m, new_v


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(g * g) for g in tree.values()))


def make_train_step(cfg, rank, full_ft, encoder=False, regression=False, use_pallas=False):
    """Return (fn, frozen_specs, trainable_specs) where fn has the flat
    signature used for AOT lowering:

      decoder: fn(tokens, loss_mask, lr, step, *frozen, *train, *m, *v)
               -> (loss, grad_norm, *new_train, *new_m, *new_v)
      encoder: fn(tokens, attn_mask, labels, lr, step, *frozen, *train, *m, *v)
               -> (loss, grad_norm, *new_train, *new_m, *new_v)
    """
    frozen_specs, train_specs = param_specs(cfg, rank, full_ft, encoder=encoder)
    fnames = [n for n, _ in frozen_specs]
    tnames = [n for n, _ in train_specs]

    def loss_of(trainable, frozen, batch):
        params = {**frozen, **trainable}
        if encoder:
            tokens, attn_mask, labels = batch
            return encoder_loss(params, tokens, attn_mask, labels, cfg, regression, use_pallas)
        tokens, loss_mask = batch
        return lm_loss(params, tokens, loss_mask, cfg, use_pallas)

    def fn(*flat):
        if encoder:
            tokens, attn_mask, labels, lr, step = flat[:5]
            batch = (tokens, attn_mask, labels)
            rest = flat[5:]
        else:
            tokens, loss_mask, lr, step = flat[:4]
            batch = (tokens, loss_mask)
            rest = flat[4:]
        nf, nt = len(fnames), len(tnames)
        frozen = dict(zip(fnames, rest[:nf]))
        trainable = dict(zip(tnames, rest[nf : nf + nt]))
        m = dict(zip(tnames, rest[nf + nt : nf + 2 * nt]))
        v = dict(zip(tnames, rest[nf + 2 * nt : nf + 3 * nt]))

        loss, grads = jax.value_and_grad(loss_of)(trainable, frozen, batch)
        gnorm = global_norm(grads)
        new_t, new_m, new_v = adamw_update(grads, trainable, m, v, lr, step)
        outs = [loss, gnorm]
        outs += [new_t[k] for k in tnames]
        outs += [new_m[k] for k in tnames]
        outs += [new_v[k] for k in tnames]
        return tuple(outs)

    return fn, frozen_specs, train_specs


def make_logits_fn(cfg, rank, full_ft, encoder=False, use_pallas=False):
    """Flat-signature eval function for AOT lowering.

    decoder: fn(tokens, *frozen, *train) -> (logits,)
    encoder: fn(tokens, attn_mask, *frozen, *train) -> (logits,)
    """
    frozen_specs, train_specs = param_specs(cfg, rank, full_ft, encoder=encoder)
    fnames = [n for n, _ in frozen_specs]
    tnames = [n for n, _ in train_specs]

    def fn(*flat):
        if encoder:
            tokens, attn_mask = flat[:2]
            rest = flat[2:]
        else:
            tokens = flat[0]
            rest = flat[1:]
        params = dict(zip(fnames + tnames, rest))
        if encoder:
            return (encoder_logits_fn(params, tokens, attn_mask, cfg, use_pallas),)
        return (logits_fn(params, tokens, cfg, use_pallas),)

    return fn, frozen_specs, train_specs


# ---------------------------------------------------------------------------
# init (used by tests and by aot.py to produce example args)
# ---------------------------------------------------------------------------


def init_params(cfg, rank, full_ft, key, encoder=False):
    """Random init of every tensor in spec order — used for tracing shapes
    and for python-side tests. The *real* base weights come from rust
    pre-training; adapters from rust PiSSA/LoRA init."""
    frozen_specs, train_specs = param_specs(cfg, rank, full_ft, encoder=encoder)
    out_f, out_t = {}, {}
    for specs, out in ((frozen_specs, out_f), (train_specs, out_t)):
        for name, shape in specs:
            key, sub = jax.random.split(key)
            if name.endswith("_norm"):
                out[name] = jnp.ones(shape, jnp.float32)
            elif name.startswith("b_") or name == "cls_head":
                out[name] = jnp.zeros(shape, jnp.float32)
            else:
                out[name] = 0.02 * jax.random.normal(sub, shape, jnp.float32)
    return out_f, out_t
