"""AOT pipeline: lower every (config, rank, strategy-shape) variant of the
L2 model to HLO **text** and write artifacts/manifest.json describing the
exact argument order the rust runtime must use.

HLO text — NOT `lowered.compiler_ir("hlo")` protos and NOT `.serialize()`
— is the interchange format: jax ≥ 0.5 emits 64-bit instruction ids that
the xla crate's xla_extension 0.5.1 rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

Run via `make artifacts` (idempotent: skips lowering when the output is
newer than the python sources).

Usage:
    python -m compile.aot --out-dir ../artifacts [--configs tiny,small]
                          [--goldens]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import configs as C
from . import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def arg_entry(name, shape, dtype):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def lower_train(cfg, rank, full_ft, encoder=False, regression=False, use_pallas=False):
    """Lower one train-step artifact; returns (hlo_text, manifest_entry)."""
    fn, frozen_specs, train_specs = M.make_train_step(
        cfg, rank, full_ft, encoder=encoder, regression=regression, use_pallas=use_pallas
    )
    b, t = cfg.batch, cfg.seq_len
    if encoder:
        data_args = [
            arg_entry("tokens", (b, t), "i32"),
            arg_entry("attn_mask", (b, t), "f32"),
            arg_entry("labels", (b,), "i32"),
            arg_entry("lr", (), "f32"),
            arg_entry("step", (), "f32"),
        ]
        data_specs = [
            spec((b, t), jnp.int32),
            spec((b, t), jnp.float32),
            spec((b,), jnp.int32),
            spec((), jnp.float32),
            spec((), jnp.float32),
        ]
    else:
        data_args = [
            arg_entry("tokens", (b, t), "i32"),
            arg_entry("loss_mask", (b, t), "f32"),
            arg_entry("lr", (), "f32"),
            arg_entry("step", (), "f32"),
        ]
        data_specs = [
            spec((b, t), jnp.int32),
            spec((b, t), jnp.float32),
            spec((), jnp.float32),
            spec((), jnp.float32),
        ]

    param_specs = [spec(s) for _, s in frozen_specs]
    train_param_specs = [spec(s) for _, s in train_specs]
    all_specs = data_specs + param_specs + train_param_specs * 3  # params, m, v

    lowered = jax.jit(fn).lower(*all_specs)
    hlo = to_hlo_text(lowered)

    args = list(data_args)
    args += [arg_entry(n, s, "f32") for n, s in frozen_specs]
    args += [arg_entry(f"{n}", s, "f32") for n, s in train_specs]
    args += [arg_entry(f"m.{n}", s, "f32") for n, s in train_specs]
    args += [arg_entry(f"v.{n}", s, "f32") for n, s in train_specs]
    outputs = [arg_entry("loss", (), "f32"), arg_entry("grad_norm", (), "f32")]
    outputs += [arg_entry(n, s, "f32") for n, s in train_specs]
    outputs += [arg_entry(f"m.{n}", s, "f32") for n, s in train_specs]
    outputs += [arg_entry(f"v.{n}", s, "f32") for n, s in train_specs]

    entry = {
        "kind": "encoder_train" if encoder else "train",
        "config": cfg.name,
        "rank": 0 if full_ft else rank,
        "full_ft": full_ft,
        "regression": regression,
        "use_pallas": use_pallas,
        "batch": b,
        "seq_len": t,
        "vocab": cfg.vocab,
        "n_frozen": len(frozen_specs),
        "n_trainable": len(train_specs),
        "frozen_names": [n for n, _ in frozen_specs],
        "trainable_names": [n for n, _ in train_specs],
        "args": args,
        "outputs": outputs,
    }
    return hlo, entry


def lower_logits(cfg, rank, full_ft, encoder=False, use_pallas=False):
    fn, frozen_specs, train_specs = M.make_logits_fn(
        cfg, rank, full_ft, encoder=encoder, use_pallas=use_pallas
    )
    b = getattr(cfg, "eval_batch", cfg.batch)
    t = cfg.seq_len
    if encoder:
        data_specs = [spec((b, t), jnp.int32), spec((b, t), jnp.float32)]
        data_args = [arg_entry("tokens", (b, t), "i32"), arg_entry("attn_mask", (b, t), "f32")]
        out_shape = (b, cfg.n_classes)
    else:
        data_specs = [spec((b, t), jnp.int32)]
        data_args = [arg_entry("tokens", (b, t), "i32")]
        out_shape = (b, t, cfg.vocab)

    all_specs = data_specs + [spec(s) for _, s in frozen_specs] + [spec(s) for _, s in train_specs]
    lowered = jax.jit(fn).lower(*all_specs)
    hlo = to_hlo_text(lowered)

    args = data_args + [arg_entry(n, s, "f32") for n, s in frozen_specs + train_specs]
    entry = {
        "kind": "encoder_logits" if encoder else "logits",
        "config": cfg.name,
        "rank": 0 if full_ft else rank,
        "full_ft": full_ft,
        "use_pallas": use_pallas,
        "batch": b,
        "seq_len": t,
        "vocab": cfg.vocab,
        "n_frozen": len(frozen_specs),
        "n_trainable": len(train_specs),
        "frozen_names": [n for n, _ in frozen_specs],
        "trainable_names": [n for n, _ in train_specs],
        "args": args,
        "outputs": [arg_entry("logits", out_shape, "f32")],
    }
    return hlo, entry


def write_goldens(out_dir):
    """Cross-language golden vectors: rust unit tests compare its NF4 and
    fast-SVD implementations against these jnp-computed references."""
    from .kernels import ref

    rng = np.random.default_rng(12345)
    flat = (rng.standard_normal(256) * 0.05).astype(np.float32)
    codes, scales = ref.nf4_quantize_ref(jnp.asarray(flat))
    rt = ref.nf4_roundtrip_ref(jnp.asarray(flat))
    w = (rng.standard_normal((48, 32)) * 0.1).astype(np.float32)
    s_exact = np.linalg.svd(w, compute_uv=False)
    golden = {
        "nf4_input": flat.tolist(),
        "nf4_codes": np.asarray(codes).tolist(),
        "nf4_scales": np.asarray(scales).tolist(),
        "nf4_roundtrip": np.asarray(rt).tolist(),
        "svd_input": w.flatten().tolist(),
        "svd_rows": 48,
        "svd_cols": 32,
        "svd_singular_values": s_exact.tolist(),
    }
    path = os.path.join(out_dir, "goldens.json")
    with open(path, "w") as f:
        json.dump(golden, f)
    print(f"wrote {path}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", default="tiny,small,e2e,enc_tiny,enc_small")
    ap.add_argument("--goldens", action="store_true", default=True)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    wanted = set(args.configs.split(","))
    manifest = {"artifacts": {}}

    def emit(name, hlo, entry):
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(hlo)
        entry["file"] = fname
        manifest["artifacts"][name] = entry
        print(f"  {fname}  ({len(hlo)//1024} KiB, {len(entry['args'])} args)")

    for cfg in C.DECODERS:
        if cfg.name not in wanted:
            continue
        print(f"[decoder {cfg.name}] d={cfg.d_model} L={cfg.n_layers} T={cfg.seq_len}")
        hlo, e = lower_train(cfg, 0, full_ft=True)
        emit(f"train_{cfg.name}_full", hlo, e)
        hlo, e = lower_logits(cfg, 0, full_ft=True)
        emit(f"logits_{cfg.name}_full", hlo, e)
        for r in cfg.ranks:
            hlo, e = lower_train(cfg, r, full_ft=False)
            emit(f"train_{cfg.name}_r{r}", hlo, e)
            hlo, e = lower_logits(cfg, r, full_ft=False)
            emit(f"logits_{cfg.name}_r{r}", hlo, e)
        if cfg.name == "tiny":
            # Kernel-path variant: proves the Pallas kernel lands in the
            # same HLO pipeline; benched against the jnp path. Inference
            # only — pallas_call(interpret=True) does not support
            # reverse-mode AD in this jax version, so the train artifacts
            # use the numerically-identical jnp path (tests assert the
            # forward outputs agree to fp tolerance).
            hlo, e = lower_logits(cfg, cfg.ranks[-1], full_ft=False, use_pallas=True)
            emit(f"logits_{cfg.name}_r{cfg.ranks[-1]}_pallas", hlo, e)

    for cfg in C.ENCODERS:
        if cfg.name not in wanted:
            continue
        print(f"[encoder {cfg.name}] d={cfg.d_model} L={cfg.n_layers} T={cfg.seq_len}")
        for full in (True, False):
            ranks = [0] if full else list(cfg.ranks)
            for r in ranks:
                for reg in (False, True):
                    tag = "full" if full else f"r{r}"
                    suffix = "reg" if reg else "cls"
                    hlo, e = lower_train(cfg, r, full_ft=full, encoder=True, regression=reg)
                    emit(f"train_{cfg.name}_{tag}_{suffix}", hlo, e)
            tag = "full" if full else f"r{cfg.ranks[0]}"
            hlo, e = lower_logits(cfg, 0 if full else cfg.ranks[0], full_ft=full, encoder=True)
            emit(f"logits_{cfg.name}_{tag}", hlo, e)

    # Echo the config table so rust can size data pipelines without
    # parsing python.
    manifest["configs"] = {
        c.name: {
            "vocab": c.vocab,
            "d_model": c.d_model,
            "n_layers": c.n_layers,
            "n_heads": c.n_heads,
            "d_ff": c.d_ff,
            "seq_len": c.seq_len,
            "batch": c.batch,
            "ranks": list(c.ranks),
            "kind": "encoder" if isinstance(c, C.EncoderConfig) else "decoder",
            "eval_batch": getattr(c, "eval_batch", c.batch),
            "n_classes": getattr(c, "n_classes", 0),
        }
        for c in C.DECODERS + C.ENCODERS
    }

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest with {len(manifest['artifacts'])} artifacts")

    if args.goldens:
        write_goldens(args.out_dir)


if __name__ == "__main__":
    main()
